//! Integration pins for the **fault-injection subsystem**
//! ([`dts::sim::faults`]):
//!
//! * the **zero-fault bit-identity** standing invariant — with
//!   [`FaultModel::None`] no fault event ever appears, every fault
//!   metric is exactly zero, and schedules/logs are bit-identical
//!   across fault seeds, shard counts and worker counts (the fault
//!   plumbing is inert unless armed);
//! * **fault-draw purity** — crash/recovery instants are a pure
//!   function of `(fault_seed, node_base + node, k)`: independent of
//!   query order, of the `Faults` instance, of the scheduling policy
//!   and of the dispatch order (every realized `node_down`/`node_up`
//!   instant equals the oracle window bitwise);
//! * **conservation + no double execution under crashes** — the run
//!   completes every task exactly once (`n_assigned == total_tasks`,
//!   one `Finish` per task), each killed attempt re-executes
//!   (`starts == kills + 1` per task), wasted-work/recovery accounting
//!   reconciles with the event log, and the realized schedule replays
//!   cleanly;
//! * **Degrade** stretches realized durations without killing anything;
//! * the **federated path** under crashes: jobs-deterministic, merge
//!   conserves every task, fault accounting survives the merge.

use std::collections::BTreeMap;

use dts::coordinator::Policy;
use dts::federation::FederatedCoordinator;
use dts::graph::Gid;
use dts::metrics::Metric;
use dts::schedule::Schedule;
use dts::schedulers::SchedulerKind;
use dts::sim::{
    replay, FaultConfig, FaultModel, Faults, Reaction, ReactiveCoordinator, SimConfig,
    SimLogEntry, SimLogKind, SimResult,
};
use dts::workloads::Dataset;

fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

fn makespan(s: &Schedule) -> f64 {
    s.iter().map(|(_, a)| a.finish).fold(0.0, f64::max)
}

fn cfg_with(seed: u64, faults: FaultConfig) -> SimConfig {
    SimConfig {
        noise_std: 0.3,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::LastK {
            k: 3,
            threshold: 0.25,
        },
        record_frozen: false,
        full_refresh: false,
        faults,
    }
}

/// A crash model scaled to the instance: windows sized off the
/// faultless makespan so several down/up cycles land inside the
/// horizon regardless of the dataset's time units.
fn scaled_crash(prob_makespan: f64, seed: u64) -> FaultConfig {
    FaultConfig {
        model: FaultModel::Crash {
            mtbf: prob_makespan / 8.0,
            mttr: prob_makespan / 40.0,
        },
        seed,
        node_base: 0,
    }
}

/// Every realized `NodeDown`/`NodeUp` instant must equal the pure
/// oracle window bitwise, in per-node window order — this is the
/// dispatch-order/policy independence pin: whatever the coordinator
/// did between crashes, the crash pattern itself never moved.
fn assert_instants_match_oracle(log: &[SimLogEntry], faults: &Faults, ctx: &str) {
    let mut next_k: BTreeMap<usize, u64> = BTreeMap::new();
    for e in log {
        match e.kind {
            SimLogKind::NodeDown { node, .. } => {
                let k = *next_k.entry(node).or_insert(0);
                let (down, _) = faults.window(node, k).expect("oracle window");
                assert_eq!(
                    e.time.to_bits(),
                    down.to_bits(),
                    "{ctx}: node {node} window {k} down instant moved"
                );
            }
            SimLogKind::NodeUp { node, downtime } => {
                let k = next_k.entry(node).or_insert(0);
                let (down, up) = faults.window(node, *k).expect("oracle window");
                assert_eq!(
                    e.time.to_bits(),
                    up.to_bits(),
                    "{ctx}: node {node} window {k} up instant moved"
                );
                assert_eq!(downtime.to_bits(), (up - down).to_bits(), "{ctx}");
                *k += 1;
            }
            _ => {}
        }
    }
}

/// Per-gid conservation over the realized log: every task finishes
/// exactly once, and a task killed `m` times started `m + 1` times —
/// no double execution, no lost re-execution.
fn assert_conservation(res: &SimResult, ctx: &str) {
    let mut starts: BTreeMap<Gid, usize> = BTreeMap::new();
    let mut finishes: BTreeMap<Gid, usize> = BTreeMap::new();
    let mut kills: BTreeMap<Gid, usize> = BTreeMap::new();
    let mut wasted_sum = 0.0;
    let mut n_kill_events = 0usize;
    for e in &res.log {
        match e.kind {
            SimLogKind::Start { gid, .. } => *starts.entry(gid).or_insert(0) += 1,
            SimLogKind::Finish { gid, .. } => *finishes.entry(gid).or_insert(0) += 1,
            SimLogKind::Kill { gid, wasted, .. } => {
                *kills.entry(gid).or_insert(0) += 1;
                wasted_sum += wasted;
                n_kill_events += 1;
            }
            _ => {}
        }
    }
    for (gid, n) in &finishes {
        assert_eq!(*n, 1, "{ctx}: {gid:?} finished {n} times");
        let s = starts.get(gid).copied().unwrap_or(0);
        let k = kills.get(gid).copied().unwrap_or(0);
        assert_eq!(s, k + 1, "{ctx}: {gid:?} started {s}× for {k} kills");
    }
    for gid in kills.keys() {
        assert!(finishes.contains_key(gid), "{ctx}: killed {gid:?} never re-ran");
    }
    assert_eq!(res.n_killed, n_kill_events, "{ctx}: n_killed");
    assert_eq!(res.n_reexecuted, kills.len(), "{ctx}: n_reexecuted");
    assert!(res.n_killed >= res.n_reexecuted, "{ctx}");
    // accumulated in event order on both sides → bitwise-equal sums
    assert_eq!(
        res.wasted_work_s.to_bits(),
        wasted_sum.to_bits(),
        "{ctx}: wasted_work_s does not reconcile with Kill events"
    );
    let n_up = res
        .log
        .iter()
        .filter(|e| matches!(e.kind, SimLogKind::NodeUp { .. }))
        .count();
    assert_eq!(res.n_recoveries, n_up, "{ctx}: n_recoveries");
}

fn has_fault_events(log: &[SimLogEntry]) -> bool {
    log.iter().any(|e| {
        matches!(
            e.kind,
            SimLogKind::NodeDown { .. } | SimLogKind::NodeUp { .. } | SimLogKind::Kill { .. }
        )
    })
}

/// ACCEPTANCE GRID: with `FaultModel::None` the fault machinery is
/// bit-inert — on all four datasets, monolithic and 4-shard, at worker
/// counts 1 and 2, under two different fault *seeds* (the seed must
/// not matter when the model is off): identical schedules and logs,
/// no fault events, all fault metrics exactly zero.
#[test]
fn zero_fault_grid_is_bit_identical() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 900 + 17 * di as u64;
        let prob = dataset.instance(6, seed);
        let ctx = dataset.name();

        let none_a = FaultConfig::NONE;
        let none_b = FaultConfig {
            model: FaultModel::None,
            seed: 0xDEAD_BEEF, // must be irrelevant with the model off
            node_base: 3,
        };
        let mono = |f: FaultConfig| {
            ReactiveCoordinator::new(
                Policy::LastK(5),
                SchedulerKind::Heft.make(seed ^ 0x5EED),
                cfg_with(seed, f),
            )
            .run(&prob)
        };
        let a = mono(none_a);
        let b = mono(none_b);
        assert_eq!(sig(&a.schedule), sig(&b.schedule), "{ctx}: fault seed leaked");
        assert_eq!(a.log, b.log, "{ctx}: fault seed leaked into the log");

        assert!(!a.faults_enabled, "{ctx}");
        assert!(!has_fault_events(&a.log), "{ctx}: fault event without a model");
        assert_eq!(a.n_killed, 0, "{ctx}");
        assert_eq!(a.n_reexecuted, 0, "{ctx}");
        assert_eq!(a.n_recoveries, 0, "{ctx}");
        assert_eq!(a.n_failure_replans(), 0, "{ctx}");
        assert_eq!(a.wasted_work_s.to_bits(), 0.0f64.to_bits(), "{ctx}");
        assert_eq!(a.mean_recovery_latency().to_bits(), 0.0f64.to_bits(), "{ctx}");
        let row = a.metrics(&prob);
        assert_eq!(row.wasted_work_s.to_bits(), 0.0f64.to_bits(), "{ctx}");
        assert_eq!(row.n_reexecuted.to_bits(), 0.0f64.to_bits(), "{ctx}");
        assert_eq!(row.mean_recovery_latency.to_bits(), 0.0f64.to_bits(), "{ctx}");

        // federated: same inertness, and jobs-bit-identical
        let fed = |f: FaultConfig, jobs: usize| {
            FederatedCoordinator::new(
                Policy::LastK(5),
                SchedulerKind::Heft,
                seed ^ 0x5EED,
                cfg_with(seed, f),
                4,
            )
            .with_jobs(jobs)
            .run(&prob)
        };
        let f1 = fed(none_a, 1);
        let f2 = fed(none_b, 2);
        assert_eq!(sig(&f1.schedule), sig(&f2.schedule), "{ctx}: federated");
        assert_eq!(f1.log, f2.log, "{ctx}: federated log");
        assert!(!has_fault_events(&f1.log), "{ctx}: federated fault event");
        assert_eq!(f1.n_killed(), 0, "{ctx}");
        assert_eq!(f1.n_reexecuted(), 0, "{ctx}");
        assert_eq!(f1.n_failure_replans(), 0, "{ctx}");
        assert_eq!(f1.wasted_work_s().to_bits(), 0.0f64.to_bits(), "{ctx}");
        assert_eq!(f1.mean_recovery_latency().to_bits(), 0.0f64.to_bits(), "{ctx}");
    }
    // the three fault axes joined the metric vocabulary
    assert_eq!(Metric::ALL.len(), 18);
}

/// Fault draws are a pure function of `(seed, node_base + node, k)`:
/// the same window regardless of query order or instance, and a shard
/// whose `node_base` is `b` sees exactly the global windows of node
/// `b + v` — the federated shard-identity contract.
#[test]
fn fault_draws_are_pure_and_shard_shifted() {
    let crash = |seed, node_base| {
        Faults::new(FaultConfig {
            model: FaultModel::Crash {
                mtbf: 50.0,
                mttr: 5.0,
            },
            seed,
            node_base,
        })
    };
    let a = crash(7, 0);
    // forward order
    let fwd: Vec<_> = (0..6u64).map(|k| a.window(2, k).unwrap()).collect();
    // a fresh instance queried backwards sees the same windows bitwise
    let b = crash(7, 0);
    for k in (0..6u64).rev() {
        let (d, u) = b.window(2, k).unwrap();
        assert_eq!(d.to_bits(), fwd[k as usize].0.to_bits(), "window {k} down");
        assert_eq!(u.to_bits(), fwd[k as usize].1.to_bits(), "window {k} up");
    }
    // node_base shift: shard-local node v ≡ global node base + v
    let shard = crash(7, 5);
    for v in 0..3usize {
        for k in 0..4u64 {
            assert_eq!(shard.window(v, k), a.window(5 + v, k), "base shift v={v} k={k}");
        }
    }
    // a different seed is a different pattern
    let c = crash(8, 0);
    assert_ne!(c.window(2, 0), a.window(2, 0));
    // the model gates everything
    let none = Faults::new(FaultConfig::NONE);
    assert_eq!(none.window(0, 0), None);
    assert!(!none.enabled());
}

/// CONSERVATION UNDER CRASHES, all four datasets: the run completes
/// every task exactly once, killed attempts re-execute, accounting
/// reconciles with the log, crash instants match the pure oracle, the
/// realized schedule replays cleanly, and the whole thing is
/// deterministic (two runs are bit-identical).
#[test]
fn crash_runs_conserve_and_never_double_execute() {
    let mut total_downs = 0usize;
    let mut total_kills = 0usize;
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 40 + di as u64;
        let prob = dataset.instance(6, seed);
        let ctx = dataset.name();

        // scale the crash cycle off the faultless makespan
        let base = ReactiveCoordinator::new(
            Policy::LastK(5),
            SchedulerKind::Heft.make(seed ^ 0x5EED),
            cfg_with(seed, FaultConfig::NONE),
        )
        .run(&prob);
        let fcfg = scaled_crash(makespan(&base.schedule), seed ^ 0xFA17);

        let run = || {
            ReactiveCoordinator::new(
                Policy::LastK(5),
                SchedulerKind::Heft.make(seed ^ 0x5EED),
                cfg_with(seed, fcfg),
            )
            .run(&prob)
        };
        let res = run();
        assert!(res.faults_enabled, "{ctx}");
        assert_eq!(
            res.schedule.n_assigned(),
            prob.total_tasks(),
            "{ctx}: crash run lost tasks"
        );
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{ctx}: {:?}", rep.errors);
        assert_conservation(&res, ctx);
        assert_instants_match_oracle(&res.log, &Faults::new(fcfg), ctx);

        let downs = res
            .log
            .iter()
            .filter(|e| matches!(e.kind, SimLogKind::NodeDown { .. }))
            .count();
        assert!(downs > 0, "{ctx}: no crash fired inside the horizon");
        total_downs += downs;
        total_kills += res.n_killed;
        if res.n_killed > 0 {
            // a killed running task forces at least one failure replan
            assert!(res.n_failure_replans() > 0, "{ctx}: kill without replan");
            assert!(res.wasted_work_s > 0.0, "{ctx}");
        }
        if res.n_recoveries > 0 {
            assert!(res.mean_recovery_latency() > 0.0, "{ctx}");
        }
        // metric plumbing carries the run's numbers bitwise
        let row = res.metrics(&prob);
        assert_eq!(row.wasted_work_s.to_bits(), res.wasted_work_s.to_bits(), "{ctx}");
        assert_eq!(row.n_reexecuted, res.n_reexecuted as f64, "{ctx}");
        assert_eq!(
            row.mean_recovery_latency.to_bits(),
            res.mean_recovery_latency().to_bits(),
            "{ctx}"
        );

        // determinism: the exact same run, bit for bit
        let again = run();
        assert_eq!(sig(&res.schedule), sig(&again.schedule), "{ctx}: nondeterministic");
        assert_eq!(res.log, again.log, "{ctx}: nondeterministic log");
        assert_eq!(res.wasted_work_s.to_bits(), again.wasted_work_s.to_bits(), "{ctx}");
    }
    assert!(total_downs >= Dataset::ALL.len(), "crash grid never crashed");
    assert!(total_kills > 0, "no run ever killed a task — grid too tame");
}

/// Crash instants are policy- and scheduler-independent: two runs with
/// different preemption policies and base heuristics observe, per
/// node, prefixes of the *same* pure window sequence.
#[test]
fn fault_pattern_is_policy_independent() {
    let dataset = Dataset::Synthetic;
    let seed = 77;
    let prob = dataset.instance(6, seed);
    let base = ReactiveCoordinator::new(
        Policy::LastK(5),
        SchedulerKind::Heft.make(seed),
        cfg_with(seed, FaultConfig::NONE),
    )
    .run(&prob);
    let fcfg = scaled_crash(makespan(&base.schedule), 0xFA17);
    let oracle = Faults::new(fcfg);

    for (policy, kind) in [
        (Policy::LastK(5), SchedulerKind::Heft),
        (Policy::NonPreemptive, SchedulerKind::Heft),
        (Policy::Preemptive, SchedulerKind::Heft),
    ] {
        let res = ReactiveCoordinator::new(policy, kind.make(seed), cfg_with(seed, fcfg))
            .run(&prob);
        let ctx = format!("{} {}", policy.label(), kind.name());
        // every observed instant is the oracle's — the schedule around
        // the crashes differs by policy, the crashes themselves do not
        assert_instants_match_oracle(&res.log, &oracle, &ctx);
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks(), "{ctx}");
    }
}

/// Degrade stretches realized durations without killing anything: the
/// run completes, replays cleanly, logs no fault events (degrade is a
/// duration effect, not a crash), counts zero kills/wasted work — and
/// actually changes the realized schedule somewhere on the grid.
#[test]
fn degrade_stretches_without_killing() {
    let mut any_changed = false;
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 60 + di as u64;
        let prob = dataset.instance(6, seed);
        let ctx = dataset.name();

        let base = ReactiveCoordinator::new(
            Policy::LastK(5),
            SchedulerKind::Heft.make(seed ^ 0x5EED),
            cfg_with(seed, FaultConfig::NONE),
        )
        .run(&prob);
        let fcfg = FaultConfig {
            model: FaultModel::Degrade {
                factor: 2.0,
                span: makespan(&base.schedule) / 6.0,
            },
            seed: seed ^ 0xFA17,
            node_base: 0,
        };
        let res = ReactiveCoordinator::new(
            Policy::LastK(5),
            SchedulerKind::Heft.make(seed ^ 0x5EED),
            cfg_with(seed, fcfg),
        )
        .run(&prob);

        assert!(res.faults_enabled, "{ctx}");
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks(), "{ctx}");
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{ctx}: {:?}", rep.errors);
        assert!(!has_fault_events(&res.log), "{ctx}: degrade logged a crash");
        assert_eq!(res.n_killed, 0, "{ctx}");
        assert_eq!(res.n_reexecuted, 0, "{ctx}");
        assert_eq!(res.wasted_work_s.to_bits(), 0.0f64.to_bits(), "{ctx}");
        assert_eq!(res.n_failure_replans(), 0, "{ctx}");
        if sig(&res.schedule) != sig(&base.schedule) {
            any_changed = true;
        }
    }
    assert!(any_changed, "Degrade(2.0) never moved a single realized time");
}

/// The federated path under crashes: bit-identical at any worker
/// count, conserves every task through the merge, fault accounting
/// survives aggregation, and the merged schedule replays cleanly.
#[test]
fn federated_crash_runs_are_jobs_deterministic_and_conserve() {
    for dataset in [Dataset::Synthetic, Dataset::RiotBench] {
        let seed = 88;
        let prob = dataset.instance(12, seed);
        let ctx = dataset.name();
        let base = ReactiveCoordinator::new(
            Policy::LastK(5),
            SchedulerKind::Heft.make(seed),
            cfg_with(seed, FaultConfig::NONE),
        )
        .run(&prob);
        let fcfg = scaled_crash(makespan(&base.schedule), 0xFA17);
        let run = |jobs: usize| {
            FederatedCoordinator::new(
                Policy::LastK(5),
                SchedulerKind::Heft,
                seed,
                cfg_with(seed, fcfg),
                4,
            )
            .with_jobs(jobs)
            .run(&prob)
        };
        let f1 = run(1);
        let f2 = run(2);
        assert_eq!(sig(&f1.schedule), sig(&f2.schedule), "{ctx}: jobs changed faults");
        assert_eq!(f1.log, f2.log, "{ctx}: jobs changed the fault log");
        assert_eq!(f1.n_killed(), f2.n_killed(), "{ctx}");
        assert_eq!(f1.wasted_work_s().to_bits(), f2.wasted_work_s().to_bits(), "{ctx}");

        assert_eq!(f1.schedule.n_assigned(), prob.total_tasks(), "{ctx}: merge lost tasks");
        let rep = replay(&f1.schedule, &prob.graphs, &prob.network);
        assert!(rep.errors.is_empty(), "{ctx}: {:?}", rep.errors);
        assert!(f1.n_killed() >= f1.n_reexecuted(), "{ctx}");
        assert!(f1.wasted_work_s() >= 0.0, "{ctx}");
        assert!(f1.mean_recovery_latency() >= 0.0, "{ctx}");
    }
}
