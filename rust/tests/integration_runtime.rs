//! PJRT round-trip integration: the XLA-compiled Pallas rank artifact
//! must agree with the native Rust rank provider on real composite
//! problems, and the EFT artifact with direct arithmetic.
//!
//! Requires `make artifacts` (skips loudly when absent so plain
//! `cargo test` works in a fresh checkout).

use dts::coordinator::{Coordinator, Policy};
use dts::graph::GraphBuilder;
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::runtime::{composite_height, XlaRanks, XlaRuntime, NEG};
use dts::schedulers::{Cpop, Heft, NativeRanks, PTask, Pred, Problem, RankProvider};
use dts::workloads::Dataset;

use std::rc::Rc;

fn runtime() -> Option<Rc<XlaRuntime>> {
    match XlaRuntime::load("artifacts") {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            eprintln!("SKIP integration_runtime: {e}");
            None
        }
    }
}

/// Random multi-component problem with `n` tasks.
fn random_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut tasks: Vec<PTask> = (0..n)
        .map(|i| PTask {
            gid: dts::graph::Gid::new(i / 16, i % 16),
            cost: rng.uniform(1.0, 50.0),
            ready: 0.0,
            preds: Vec::new(),
            succs: Vec::new(),
        })
        .collect();
    for i in 0..n {
        for j in (i + 1)..n {
            // edges only within the same 16-task block → components
            if j / 16 == i / 16 && rng.next_f64() < 0.25 {
                let data = rng.uniform(0.5, 20.0);
                tasks[i].succs.push((j, data));
                tasks[j].preds.push(Pred::Pending { idx: i, data });
            }
        }
    }
    Problem::from_tasks(tasks)
}

#[test]
fn xla_ranks_match_native_across_sizes() {
    let Some(rt) = runtime() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let net = Network::default_eval(&mut rng);
    for &n in &[3usize, 10, 31, 32, 33, 64, 100, 200, 256] {
        let prob = random_problem(n, n as u64);
        let native = NativeRanks.ranks(&prob, &net);
        let mut xr = XlaRanks::new(rt.clone());
        let xla = xr.ranks(&prob, &net);
        assert_eq!(xr.xla_calls, 1, "n={n} should use the artifact");
        for i in 0..n {
            let rel = (native.up[i] - xla.up[i]).abs() / (1.0 + native.up[i].abs());
            assert!(rel < 1e-4, "up[{i}] native {} xla {} (n={n})", native.up[i], xla.up[i]);
            let rel = (native.down[i] - xla.down[i]).abs() / (1.0 + native.down[i].abs());
            assert!(rel < 1e-4, "down[{i}] native {} xla {} (n={n})", native.down[i], xla.down[i]);
        }
    }
}

#[test]
fn oversize_problems_fall_back_to_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let net = Network::default_eval(&mut rng);
    let prob = random_problem(300, 9); // > max bucket (256)
    let mut xr = XlaRanks::new(rt);
    let _ = xr.ranks(&prob, &net);
    assert_eq!(xr.native_calls, 1);
    assert_eq!(xr.xla_calls, 0);
}

#[test]
fn heft_with_xla_ranks_produces_equivalent_schedules() {
    let Some(rt) = runtime() else { return };
    // rank parity must translate into schedule parity (same priorities →
    // same placements, up to fp tie-breaks which the tolerance absorbs)
    let prob = Dataset::Synthetic.instance(10, 77);
    let mut native = Coordinator::new(Policy::LastK(5), Box::new(Heft::new(NativeRanks)));
    let res_native = native.run(&prob);
    let mut xla = Coordinator::new(
        Policy::LastK(5),
        Box::new(Heft::new(XlaRanks::new(rt.clone()))),
    );
    let res_xla = xla.run(&prob);
    let m_native = res_native.metrics(&prob);
    let m_xla = res_xla.metrics(&prob);
    let rel =
        (m_native.total_makespan - m_xla.total_makespan).abs() / m_native.total_makespan;
    assert!(
        rel < 1e-3,
        "makespan native {} vs xla {}",
        m_native.total_makespan,
        m_xla.total_makespan
    );

    // CPOP too
    let mut cn = Coordinator::new(Policy::Preemptive, Box::new(Cpop::new(NativeRanks)));
    let mut cx = Coordinator::new(Policy::Preemptive, Box::new(Cpop::new(XlaRanks::new(rt))));
    let a = cn.run(&prob).metrics(&prob);
    let b = cx.run(&prob).metrics(&prob);
    let rel = (a.total_makespan - b.total_makespan).abs() / a.total_makespan;
    assert!(rel < 1e-3, "cpop {} vs {}", a.total_makespan, b.total_makespan);
}

#[test]
fn xla_schedules_are_valid() {
    let Some(rt) = runtime() else { return };
    let prob = Dataset::RiotBench.instance(12, 3);
    let mut c = Coordinator::new(Policy::LastK(2), Box::new(Heft::new(XlaRanks::new(rt))));
    let res = c.run(&prob);
    let viol = dts::schedule::validate(&res.schedule, &prob.graphs, &prob.network);
    assert!(viol.is_empty(), "{viol:?}");
    let rep = dts::sim::replay(&res.schedule, &prob.graphs, &prob.network);
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
}

#[test]
fn eft_artifact_matches_direct_arithmetic() {
    let Some(rt) = runtime() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(12);
    for &n_nodes in &[4usize, 8, 13, 32] {
        let Some((p_bucket, v_bucket)) = rt.eft_bucket(n_nodes) else {
            panic!("no eft bucket for {n_nodes} nodes");
        };
        let n_par = 5usize.min(p_bucket);
        let mut finish = vec![NEG; p_bucket];
        let mut comm = vec![0f32; p_bucket * v_bucket];
        for i in 0..n_par {
            finish[i] = rng.uniform(0.0, 40.0) as f32;
            for j in 0..n_nodes {
                comm[i * v_bucket + j] = rng.uniform(0.0, 10.0) as f32;
            }
        }
        let mut exec = vec![0f32; v_bucket];
        let mut avail = vec![0f32; v_bucket];
        for j in 0..n_nodes {
            exec[j] = rng.uniform(0.5, 20.0) as f32;
            avail[j] = rng.uniform(0.0, 30.0) as f32;
        }
        let arrival = 7.5f32;
        let out = rt
            .batch_eft_padded(v_bucket, &finish, &comm, &exec, &avail, arrival)
            .unwrap();
        for j in 0..n_nodes {
            let mut ready = arrival.max(avail[j]);
            for i in 0..n_par {
                ready = ready.max(finish[i] + comm[i * v_bucket + j]);
            }
            let want = ready + exec[j];
            assert!(
                (out[j] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "node {j}: xla {} vs direct {want}",
                out[j]
            );
        }
    }
}

#[test]
fn composite_height_drives_convergence() {
    let Some(rt) = runtime() else { return };
    // a deep chain exactly at bucket size: depth = n must converge
    let n = 32;
    let mut b = GraphBuilder::new("deep");
    let ids: Vec<_> = (0..n).map(|_| b.task(2.0)).collect();
    for w in ids.windows(2) {
        b.edge(w[0], w[1], 1.0);
    }
    let g = b.build().unwrap();
    let mut tasks: Vec<PTask> = (0..n)
        .map(|t| PTask {
            gid: dts::graph::Gid::new(0, t),
            cost: g.cost(t),
            ready: 0.0,
            preds: Vec::new(),
            succs: Vec::new(),
        })
        .collect();
    for t in 0..n {
        for &(c, d) in g.successors(t) {
            tasks[t].succs.push((c, d));
            tasks[c].preds.push(Pred::Pending { idx: t, data: d });
        }
    }
    let prob = Problem::from_tasks(tasks);
    assert_eq!(composite_height(&prob), n);
    let net = Network::homogeneous(4);
    let native = NativeRanks.ranks(&prob, &net);
    let mut xr = XlaRanks::new(rt);
    let xla = xr.ranks(&prob, &net);
    for i in 0..n {
        let rel = (native.up[i] - xla.up[i]).abs() / (1.0 + native.up[i].abs());
        assert!(rel < 1e-4, "up[{i}]");
    }
}

#[test]
fn allpairs_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Xoshiro256pp::seed_from_u64(21);
    let net = Network::default_eval(&mut rng);
    for &n in &[10usize, 32, 60, 128] {
        let prob = random_problem(n, 1000 + n as u64);
        let native = dts::analysis::allpairs_longest_native(&prob, &net);
        let bucket = rt.allpairs_bucket(n).expect("bucket");
        // build the padded edge matrix with the same semantics:
        // m[u][c] = mean_comm(u,c) + mean_exec(c)
        let inv_speed = net.mean_inv_speed() as f32;
        let inv_link = net.mean_inv_link() as f32;
        let mut m = vec![NEG; bucket * bucket];
        for (u, t) in prob.tasks.iter().enumerate() {
            for &(c, data) in &t.succs {
                m[u * bucket + c] =
                    data as f32 * inv_link + prob.tasks[c].cost as f32 * inv_speed;
            }
        }
        let d = rt.allpairs_padded(bucket, &m).unwrap();
        for u in 0..n {
            for v in 0..n {
                let want = native[u][v];
                let got = d[u * bucket + v] as f64;
                if want <= dts::analysis::NEG_D / 2.0 {
                    assert!(got <= dts::analysis::NEG_D / 4.0, "({u},{v}) reachable in xla only");
                } else {
                    let rel = (want - got).abs() / (1.0 + want.abs());
                    assert!(rel < 1e-4, "({u},{v}): native {want} xla {got} (n={n})");
                }
            }
        }
    }
}

#[test]
fn slack_analysis_identifies_adversarial_root() {
    // the adversarial instance's heavy root must be the top critical task
    let prob = Dataset::Adversarial.instance(1, 3);
    let g = &prob.graphs[0].1;
    let mut tasks = Vec::new();
    for t in 0..g.n_tasks() {
        tasks.push(dts::schedulers::PTask {
            gid: dts::graph::Gid::new(0, t),
            cost: g.cost(t),
            ready: 0.0,
            preds: Vec::new(),
            succs: Vec::new(),
        });
    }
    for t in 0..g.n_tasks() {
        for &(c, d) in g.successors(t) {
            tasks[t].succs.push((c, d));
            tasks[c].preds.push(dts::schedulers::Pred::Pending { idx: t, data: d });
        }
    }
    let prob2 = Problem::from_tasks(tasks);
    let r = dts::analysis::slack_analysis(&prob2, &prob.network);
    let crit = r.critical_tasks(1e-9);
    assert_eq!(crit[0], 0, "heavy root must lead the critical list");
}
