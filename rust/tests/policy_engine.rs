//! Integration harness for the preemption policy engine:
//!
//! * **Bit-exact equivalence** — [`dts::policy::FixedLastK`] driven
//!   through `ReactiveCoordinator::with_policy` must reproduce the PR-2
//!   `Reaction::LastK` path (same replans, same realized schedule, bit
//!   for bit) on all four datasets, and the sweep-level
//!   `PolicySpec::FixedLastK` cells must reproduce the sim-sweep's
//!   `Reaction::LastK` cells.
//! * **Determinism** — the joint k × θ × budget policy sweep is
//!   bit-identical at `--jobs` 1, 2 and 8.
//! * **Budget property** — a [`dts::policy::Budgeted`] controller never
//!   reverts more tasks than its token bucket allows:
//!   `straggler-reverted ≤ burst + rate × elapsed` on every run.
//! * **Hysteresis** — a zero cooldown is transparent; an effectively
//!   infinite cooldown fires at most once.

use dts::coordinator::Policy;
use dts::experiments::{
    run_policy_sweep_parallel, run_sim_sweep, PolicyScenario, PolicySweepConfig, SimScenario,
    SimSweepConfig,
};
use dts::graph::Gid;
use dts::policy::PolicySpec;
use dts::schedule::Schedule;
use dts::schedulers::SchedulerKind;
use dts::sim::{replay, Reaction, ReactiveCoordinator, SimConfig, SimResult};
use dts::workloads::{Dataset, Scenario};

fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

fn run_reaction(prob: &dts::coordinator::DynamicProblem, cfg: SimConfig) -> SimResult {
    let mut rc = ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(0), cfg);
    rc.run(prob)
}

fn run_spec(
    prob: &dts::coordinator::DynamicProblem,
    mut cfg: SimConfig,
    spec: &PolicySpec,
) -> SimResult {
    cfg.reaction = Reaction::None;
    let mut rc = ReactiveCoordinator::with_policy(
        Policy::LastK(5),
        SchedulerKind::Heft.make(0),
        cfg,
        spec.make(),
    );
    rc.run(prob)
}

/// The acceptance pin: `FixedLastK` through the policy engine is
/// bit-exactly the PR-2 `Reaction::LastK` event loop, on all four
/// datasets, replans and realized placements alike.
#[test]
fn fixed_lastk_matches_reaction_path_on_all_datasets() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 300 + 13 * di as u64;
        let prob = dataset.instance(10, seed);
        let (k, threshold) = (3, 0.05);
        let cfg = SimConfig {
            noise_std: 0.55,
            noise_seed: seed ^ 0xACE,
            reaction: Reaction::LastK { k, threshold },
            record_frozen: false,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let want = run_reaction(&prob, cfg);
        let got = run_spec(&prob, cfg, &PolicySpec::FixedLastK { k, threshold });
        assert_eq!(
            sig(&got.schedule),
            sig(&want.schedule),
            "{} realized schedules diverged",
            dataset.name()
        );
        assert_eq!(got.log.len(), want.log.len(), "{}", dataset.name());
        assert_eq!(got.n_replans(), want.n_replans(), "{}", dataset.name());
        assert_eq!(
            got.n_straggler_replans(),
            want.n_straggler_replans(),
            "{}",
            dataset.name()
        );
        assert_eq!(
            got.n_reverted_total(),
            want.n_reverted_total(),
            "{}",
            dataset.name()
        );
        assert!(
            want.n_straggler_replans() > 0,
            "{}: config should actually exercise the straggler path",
            dataset.name()
        );
    }
}

/// Sweep-level equivalence: a `PolicySpec::FixedLastK` scenario in the
/// policy sweep reproduces the PR-2 `L{k}@{θ}` sim-sweep cell bit-for-
/// bit (same instances, same noise seeds, same variant seeds).
#[test]
fn policy_sweep_reproduces_sim_sweep_lastk_cells() {
    let variant = dts::coordinator::Variant::parse("5P-HEFT").unwrap();
    let (k, threshold, noise) = (3, 0.2, 0.4);
    let sim_cfg = SimSweepConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 8,
        trials: 2,
        seed: 9,
        load: 0.5,
        variant,
        scenario: Scenario::default(),
        scenarios: vec![SimScenario {
            noise_std: noise,
            reaction: Reaction::LastK { k, threshold },
        }],
        shards: 1,
        faults: dts::sim::FaultConfig::NONE,
    };
    let pol_cfg = PolicySweepConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 8,
        trials: 2,
        seed: 9,
        load: 0.5,
        variant,
        scenario: Scenario::default(),
        scenarios: vec![PolicyScenario {
            noise_std: noise,
            spec: PolicySpec::FixedLastK { k, threshold },
        }],
        faults: dts::sim::FaultConfig::NONE,
    };
    let a = run_sim_sweep(&sim_cfg);
    let b = run_policy_sweep_parallel(&pol_cfg, 1);
    // labels line up because FixedLastK's label IS the reaction label
    assert_eq!(a.labels, b.labels);
    for trial in 0..2 {
        let sc = &a.rows[trial][0];
        let pc = &b.rows[trial][0];
        assert_eq!(
            sc.realized.total_makespan.to_bits(),
            pc.realized.total_makespan.to_bits(),
            "trial {trial}"
        );
        assert_eq!(
            sc.realized.mean_stretch.to_bits(),
            pc.realized.mean_stretch.to_bits()
        );
        assert_eq!(
            sc.realized.jain_fairness.to_bits(),
            pc.realized.jain_fairness.to_bits()
        );
        assert_eq!(sc.planned.total_makespan.to_bits(), pc.planned.total_makespan.to_bits());
        assert_eq!(sc.n_replans, pc.cost.replans);
        assert_eq!(sc.n_straggler_replans, pc.cost.straggler_replans);
        assert_eq!(sc.n_reverted, pc.cost.reverted_tasks);
    }
}

/// Joint-grid determinism: every schedule-derived metric and every
/// replan/revert count is bit-identical at any `--jobs`.
#[test]
fn policy_sweep_is_deterministic_across_jobs_1_2_8() {
    let mut scenarios = Vec::new();
    for &threshold in &[0.15, 0.3] {
        for &k in &[2, 4] {
            scenarios.push(PolicyScenario {
                noise_std: 0.35,
                spec: PolicySpec::FixedLastK { k, threshold },
            });
            scenarios.push(PolicyScenario {
                noise_std: 0.35,
                spec: PolicySpec::Budgeted {
                    k,
                    threshold,
                    rate: 0.05,
                    burst: 3.0,
                },
            });
        }
        scenarios.push(PolicyScenario {
            noise_std: 0.35,
            spec: PolicySpec::AdaptiveK {
                k0: 2,
                k_max: 8,
                threshold,
                target_stretch: 1.5,
            },
        });
    }
    let cfg = PolicySweepConfig {
        dataset: Dataset::RiotBench,
        n_graphs: 6,
        trials: 2,
        seed: 17,
        load: 0.5,
        variant: dts::coordinator::Variant::parse("5P-HEFT").unwrap(),
        scenario: Scenario::default(),
        scenarios,
        faults: dts::sim::FaultConfig::NONE,
    };
    let serial = run_policy_sweep_parallel(&cfg, 1);
    let cell_sig = |c: &dts::experiments::PolicyCell| {
        (
            c.realized.total_makespan.to_bits(),
            c.realized.mean_makespan.to_bits(),
            c.realized.mean_flowtime.to_bits(),
            c.realized.mean_utilization.to_bits(),
            c.realized.mean_stretch.to_bits(),
            c.realized.max_stretch.to_bits(),
            c.realized.jain_fairness.to_bits(),
            c.realized.weighted_mean_stretch.to_bits(),
            c.realized.weighted_max_stretch.to_bits(),
            c.realized.weighted_jain.to_bits(),
            c.cost.replans,
            c.cost.straggler_replans,
            c.cost.reverted_tasks,
        )
    };
    for jobs in [2, 8] {
        let par = run_policy_sweep_parallel(&cfg, jobs);
        assert_eq!(serial.labels, par.labels);
        for (trial, (rs, rp)) in serial.rows.iter().zip(par.rows.iter()).enumerate() {
            for (si, (a, b)) in rs.iter().zip(rp.iter()).enumerate() {
                assert_eq!(
                    cell_sig(a),
                    cell_sig(b),
                    "jobs={jobs}, trial {trial}, scenario {}",
                    serial.labels[si]
                );
            }
        }
    }
}

/// PROPERTY: a budgeted controller can never revert more tasks via
/// straggler replans than its token bucket ever issued:
/// `Σ straggler-reverted ≤ burst + rate × (last event time)`.
/// The last event time is bounded by the realized schedule's maximum
/// finish (arrivals start at 0 for generated instances).
#[test]
fn budgeted_never_exceeds_token_budget() {
    // a tight bucket (the property stress) and a generous one (which
    // must actually buy productive reverts — guards against the budget
    // path silently degenerating into no-preemption)
    let mut total_spent = 0usize;
    for (rate, burst) in [(0.03, 2.0), (0.5, 8.0)] {
        for (di, dataset) in Dataset::ALL.iter().enumerate() {
            for (si, seed) in [5u64, 23].into_iter().enumerate() {
                let prob = dataset.instance(10, seed + di as u64);
                let cfg = SimConfig {
                    noise_std: 0.5,
                    noise_seed: seed ^ 0xB00C,
                    reaction: Reaction::None,
                    record_frozen: false,
                    full_refresh: false,
                    faults: dts::sim::FaultConfig::NONE,
                };
                let res = run_spec(
                    &prob,
                    cfg,
                    &PolicySpec::Budgeted {
                        k: 5,
                        threshold: 0.05,
                        rate,
                        burst,
                    },
                );
                assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
                let rep = replay(&res.schedule, &prob.graphs, &prob.network);
                assert!(
                    rep.errors.is_empty(),
                    "{:?}",
                    &rep.errors[..rep.errors.len().min(3)]
                );
                let span = res
                    .schedule
                    .iter()
                    .map(|(_, a)| a.finish)
                    .fold(0.0, f64::max);
                let budget = burst + rate * span;
                let spent = res.n_straggler_reverted_total();
                assert!(
                    spent as f64 <= budget + 1e-9,
                    "{} seed-case {si} r{rate}b{burst}: reverted {spent} > budget {budget}",
                    dataset.name()
                );
                total_spent += spent;
            }
        }
    }
    assert!(
        total_spent > 0,
        "no Budgeted run ever reverted a task — the budget path is a no-op"
    );
}

/// The budget cap binds in practice: under heavy noise and a tight
/// threshold, the uncapped controller reverts strictly more than a
/// starved token bucket.
#[test]
fn tight_budget_reverts_less_than_uncapped() {
    let prob = Dataset::Synthetic.instance(14, 31);
    let cfg = SimConfig {
        noise_std: 0.6,
        noise_seed: 8,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    let (k, threshold) = (5, 0.05);
    let uncapped = run_spec(&prob, cfg, &PolicySpec::FixedLastK { k, threshold });
    let starved = run_spec(
        &prob,
        cfg,
        &PolicySpec::Budgeted {
            k,
            threshold,
            rate: 1e-6,
            burst: 1.0,
        },
    );
    assert!(
        uncapped.n_straggler_reverted_total() > 0,
        "config must exercise straggler reverts"
    );
    // a bucket that never refills can spend at most its initial burst
    assert!(starved.n_straggler_reverted_total() <= 1);
    assert!(
        starved.n_straggler_reverted_total() < uncapped.n_straggler_reverted_total()
    );
}

/// Cooldown semantics: zero cooldown is bit-exactly transparent, and an
/// effectively infinite cooldown fires at most one straggler replan.
#[test]
fn cooldown_zero_is_transparent_and_infinite_fires_once() {
    let prob = Dataset::Adversarial.instance(10, 4);
    let cfg = SimConfig {
        noise_std: 0.55,
        noise_seed: 6,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    let inner = PolicySpec::FixedLastK {
        k: 4,
        threshold: 0.05,
    };
    let bare = run_spec(&prob, cfg, &inner);
    let cd0 = run_spec(
        &prob,
        cfg,
        &PolicySpec::Cooldown {
            cooldown: 0.0,
            inner: Box::new(inner.clone()),
        },
    );
    assert_eq!(sig(&bare.schedule), sig(&cd0.schedule));
    assert_eq!(bare.n_replans(), cd0.n_replans());

    let cd_inf = run_spec(
        &prob,
        cfg,
        &PolicySpec::Cooldown {
            cooldown: 1e18,
            inner: Box::new(inner),
        },
    );
    assert!(cd_inf.n_straggler_replans() <= 1);
    assert!(bare.n_straggler_replans() > 1, "config must fire repeatedly");
}

/// AdaptiveK stays replay-valid on every dataset and never moves a
/// started task, whatever trajectory its window width takes.
#[test]
fn adaptive_k_is_valid_on_all_datasets() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let prob = dataset.instance(10, 60 + di as u64);
        let cfg = SimConfig {
            noise_std: 0.55,
            noise_seed: 41,
            reaction: Reaction::None,
            record_frozen: true,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let res = run_spec(
            &prob,
            cfg,
            &PolicySpec::AdaptiveK {
                k0: 2,
                k_max: 10,
                threshold: 0.05,
                target_stretch: 1.2,
            },
        );
        assert_eq!(res.schedule.n_assigned(), prob.total_tasks());
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            rep.errors.is_empty(),
            "{}: {:?}",
            dataset.name(),
            &rep.errors[..rep.errors.len().min(3)]
        );
        // frozen-prefix invariant under the policy engine
        for rec in &res.replans {
            for &(gid, node, start) in &rec.frozen {
                let a = res.schedule.get(gid).unwrap();
                assert_eq!(
                    (a.node, a.start.to_bits()),
                    (node, start.to_bits()),
                    "{}: replan at {} moved started {gid}",
                    dataset.name(),
                    rec.time
                );
            }
        }
    }
}
