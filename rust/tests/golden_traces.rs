//! Golden-trace regression fixtures: one small instance per dataset ×
//! {NP, 5P, P} policy, run through the static coordinator, serialized
//! via [`dts::trace::to_json`], and compared **bit-exactly** (schedule
//! and every metric) against the committed JSON fixture in
//! `rust/tests/golden/`.
//!
//! Bootstrap protocol (the development container has no Rust toolchain,
//! so fixtures cannot be pre-generated offline): when a fixture file is
//! missing, the test still verifies the full serialize → text → parse →
//! metrics pipeline bit-exactly against the live run, and writes the
//! fixture when `DTS_WRITE_GOLDEN=1`.  The first toolchain-equipped run
//! materializes the fixtures:
//!
//! ```text
//! DTS_WRITE_GOLDEN=1 cargo test --test golden_traces
//! git add rust/tests/golden/*.json
//! ```
//!
//! after which every future refactor of the coordinator/schedulers is
//! pinned to these exact schedules.

use std::path::PathBuf;

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::json::Value;
use dts::metrics::MetricRow;
use dts::schedulers::SchedulerKind;
use dts::trace;
use dts::workloads::Dataset;

const N_GRAPHS: usize = 6;
const SEED: u64 = 11;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn policies() -> [(&'static str, Policy); 3] {
    [
        ("NP", Policy::NonPreemptive),
        ("5P", Policy::LastK(5)),
        ("P", Policy::Preemptive),
    ]
}

fn metric_bits(schedule: &dts::schedule::Schedule, prob: &DynamicProblem) -> Vec<u64> {
    let m = MetricRow::compute(schedule, &prob.graphs, &prob.network, 0.0);
    vec![
        m.total_makespan.to_bits(),
        m.mean_makespan.to_bits(),
        m.mean_flowtime.to_bits(),
        m.mean_utilization.to_bits(),
        m.mean_stretch.to_bits(),
        m.max_stretch.to_bits(),
        m.jain_fairness.to_bits(),
    ]
}

#[test]
fn golden_traces_pin_coordinator_output() {
    for dataset in Dataset::ALL {
        for (pname, policy) in policies() {
            let prob = dataset.instance(N_GRAPHS, SEED);
            let mut coord = Coordinator::new(policy, SchedulerKind::Heft.make(SEED));
            let res = coord.run(&prob);
            let live = trace::to_json(&prob, &res);
            let ctx = format!("{}_{}", dataset.name(), pname);
            let path = golden_dir().join(format!("{ctx}.json"));

            if path.exists() {
                let text = std::fs::read_to_string(&path)
                    .unwrap_or_else(|e| panic!("{ctx}: unreadable fixture: {e}"));
                let fixture = trace::from_json(&Value::from_str(&text).unwrap())
                    .unwrap_or_else(|e| panic!("{ctx}: bad fixture: {e}"));
                assert_eq!(
                    fixture.schedule.n_assigned(),
                    res.schedule.n_assigned(),
                    "{ctx}: task count drifted"
                );
                for (gid, a) in res.schedule.iter() {
                    let b = fixture
                        .schedule
                        .get(*gid)
                        .unwrap_or_else(|| panic!("{ctx}: {gid} missing from fixture"));
                    assert_eq!(a.node, b.node, "{ctx}: {gid} node drifted");
                    assert_eq!(
                        a.start.to_bits(),
                        b.start.to_bits(),
                        "{ctx}: {gid} start drifted ({} vs {})",
                        a.start,
                        b.start
                    );
                    assert_eq!(
                        a.finish.to_bits(),
                        b.finish.to_bits(),
                        "{ctx}: {gid} finish drifted"
                    );
                }
                assert_eq!(
                    metric_bits(&res.schedule, &prob),
                    metric_bits(&fixture.schedule, &prob),
                    "{ctx}: metrics drifted from fixture"
                );
            } else {
                // bootstrap path: the JSON pipeline itself must still be
                // bit-exact through text
                let parsed = trace::from_json(&Value::from_str(&live.to_string()).unwrap())
                    .unwrap_or_else(|e| panic!("{ctx}: roundtrip parse failed: {e}"));
                assert_eq!(parsed.schedule.n_assigned(), res.schedule.n_assigned());
                for (gid, a) in res.schedule.iter() {
                    assert_eq!(parsed.schedule.get(*gid), Some(a), "{ctx}: {gid}");
                }
                assert_eq!(
                    metric_bits(&res.schedule, &prob),
                    metric_bits(&parsed.schedule, &prob),
                    "{ctx}: metrics not JSON-stable"
                );
                if std::env::var("DTS_WRITE_GOLDEN").as_deref() == Ok("1") {
                    std::fs::create_dir_all(golden_dir()).unwrap();
                    std::fs::write(&path, format!("{live}\n")).unwrap();
                    eprintln!("golden: wrote {}", path.display());
                } else {
                    eprintln!(
                        "golden: fixture {} missing — roundtrip-checked the live run; \
                         run with DTS_WRITE_GOLDEN=1 to materialize it",
                        path.display()
                    );
                }
            }
        }
    }
}

/// The golden instances must themselves be schedulable deterministically
/// — two fresh runs produce bit-identical traces (precondition for the
/// fixtures being stable at all).
#[test]
fn golden_instances_are_deterministic() {
    for dataset in Dataset::ALL {
        let (_, policy) = policies()[1];
        let run = || {
            let prob = dataset.instance(N_GRAPHS, SEED);
            let mut coord = Coordinator::new(policy, SchedulerKind::Heft.make(SEED));
            let res = coord.run(&prob);
            trace::to_json(&prob, &res).to_string()
        };
        let a = run();
        let b = run();
        // sched_runtime_s is wall time and may differ; compare the
        // structural parts via parsed assignments instead of raw text
        let ta = trace::from_json(&Value::from_str(&a).unwrap()).unwrap();
        let tb = trace::from_json(&Value::from_str(&b).unwrap()).unwrap();
        assert_eq!(ta.schedule.n_assigned(), tb.schedule.n_assigned());
        for (gid, x) in ta.schedule.iter() {
            assert_eq!(tb.schedule.get(*gid), Some(x), "{}: {gid}", dataset.name());
        }
    }
}
