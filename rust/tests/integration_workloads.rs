//! Workload-suite integration: dataset-level properties the §VI setup
//! promises (counts, mixes, CCR control, reproducibility, arrival
//! process shape).

use dts::prng::Xoshiro256pp;
use dts::stats::mean;
use dts::workloads::{adversarial, measure_ccr, riotbench, synthetic, wfcommons, Dataset};

#[test]
fn default_counts_match_paper() {
    assert_eq!(Dataset::Synthetic.default_n_graphs(), 100);
    assert_eq!(Dataset::RiotBench.default_n_graphs(), 100);
    assert_eq!(Dataset::WfCommons.default_n_graphs(), 50);
}

#[test]
fn synthetic_structure_split_is_even() {
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let gs = synthetic::generate(100, &mut rng);
    for prefix in ["out_tree", "in_tree", "fork_join", "chain"] {
        let c = gs.iter().filter(|g| g.name().starts_with(prefix)).count();
        assert_eq!(c, 25, "{prefix}");
    }
}

#[test]
fn riotbench_mix_is_roughly_uniform() {
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let gs = riotbench::generate(400, &mut rng);
    for p in riotbench::Pipeline::ALL {
        let c = gs.iter().filter(|g| g.name() == p.name()).count();
        assert!(
            (60..=140).contains(&c),
            "{} appears {c}/400 times",
            p.name()
        );
    }
}

#[test]
fn wfcommons_50_graph_default_covers_all_types() {
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let gs = wfcommons::generate(50, &mut rng);
    let names: std::collections::HashSet<_> = gs.iter().map(|g| g.name()).collect();
    assert_eq!(names.len(), 9);
}

#[test]
fn adversarial_instances_have_dominant_roots_and_low_ccr() {
    let prob = Dataset::Adversarial.instance(15, 4);
    for (_, g) in &prob.graphs {
        // root is task 0 and dominates
        let root = g.cost(0);
        let leaves: Vec<f64> = (1..g.n_tasks()).map(|t| g.cost(t)).collect();
        assert!(root > 10.0 * mean(&leaves));
        let ccr = measure_ccr(g, &prob.network);
        assert!((ccr - 0.2).abs() < 1e-9, "ccr {ccr}");
    }
}

#[test]
fn adversarial_raw_generator_roots() {
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let gs = adversarial::generate(10, &mut rng);
    for g in &gs {
        assert!(g.is_source(0));
        assert_eq!(g.height(), 2);
    }
}

#[test]
fn instances_are_fully_reproducible() {
    for dataset in Dataset::ALL {
        let a = dataset.instance(10, 99);
        let b = dataset.instance(10, 99);
        assert_eq!(a.total_tasks(), b.total_tasks());
        for ((ta, ga), (tb, gb)) in a.graphs.iter().zip(&b.graphs) {
            assert_eq!(ta, tb);
            assert_eq!(ga.n_tasks(), gb.n_tasks());
            for t in 0..ga.n_tasks() {
                assert_eq!(ga.cost(t), gb.cost(t));
            }
        }
    }
}

#[test]
fn arrival_process_creates_overlap() {
    // the default load factor must make consecutive graphs overlap in
    // time for at least part of the trace — otherwise the dynamic study
    // degenerates to static scheduling
    use dts::coordinator::{Coordinator, Policy};
    use dts::schedulers::SchedulerKind;
    let prob = Dataset::Synthetic.instance(30, 12);
    let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Heft.make(0));
    let res = c.run(&prob);
    let reverted: usize = res.events.iter().map(|e| e.n_reverted).sum();
    assert!(reverted > 0, "no overlap at default load");
}

#[test]
fn all_dataset_graphs_pass_topological_sanity() {
    for dataset in Dataset::ALL {
        let prob = dataset.instance(20, 21);
        for (_, g) in &prob.graphs {
            assert!(g.n_tasks() > 0);
            assert_eq!(g.topo_order().len(), g.n_tasks());
            assert!(g.height() >= 1);
            for t in 0..g.n_tasks() {
                assert!(g.cost(t) > 0.0);
            }
        }
    }
}
