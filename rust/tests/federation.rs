//! Integration pins for the **federated sharding layer**
//! ([`dts::federation`]):
//!
//! * the **1-shard differential oracle** — `--shards 1` reproduces the
//!   monolithic reactive coordinator bit-exactly (schedules, event logs,
//!   every metric axis) on all four datasets × the extended heuristic
//!   set;
//! * **jobs-determinism** — a sharded run is bit-identical at any
//!   worker count;
//! * **admission conservation** — every graph runs on exactly one
//!   shard, and a migrated graph never re-executes realized work (the
//!   merge would panic on a double assignment);
//! * the **frozen-prefix invariant per shard** — shard-local replans
//!   never move a task that already started on that shard.

use dts::coordinator::Policy;
use dts::federation::FederatedCoordinator;
use dts::graph::Gid;
use dts::metrics::{Metric, MetricRow};
use dts::schedule::Schedule;
use dts::schedulers::SchedulerKind;
use dts::sim::{replay, Reaction, ReactiveCoordinator, SimConfig};
use dts::workloads::Dataset;

fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

fn reactive_cfg(seed: u64, record_frozen: bool) -> SimConfig {
    SimConfig {
        noise_std: 0.3,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::LastK {
            k: 3,
            threshold: 0.25,
        },
        record_frozen,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    }
}

/// DIFFERENTIAL ORACLE: one shard ≡ the monolithic coordinator, bit for
/// bit — schedule, realized-event log, and all 18 metric axes — on all
/// four datasets across the extended heuristic set.
#[test]
fn one_shard_is_bit_identical_to_monolithic() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for (ki, kind) in SchedulerKind::EXTENDED.iter().enumerate() {
            let seed = 700 + 61 * di as u64 + 13 * ki as u64;
            let prob = dataset.instance(6, seed);
            let cfg = reactive_cfg(seed, false);
            let ctx = format!("{} {}", dataset.name(), kind.name());

            let mut mono =
                ReactiveCoordinator::new(Policy::LastK(5), kind.make(seed ^ 0x5EED), cfg);
            let m = mono.run(&prob);
            let fed = FederatedCoordinator::new(Policy::LastK(5), *kind, seed ^ 0x5EED, cfg, 1);
            let f = fed.run(&prob);

            assert_eq!(f.shard_nodes.len(), 1, "{ctx}");
            assert_eq!(sig(&m.schedule), sig(&f.schedule), "{ctx}: schedule diverged");
            assert_eq!(m.log.len(), f.log.len(), "{ctx}: log length diverged");
            for (i, (a, b)) in m.log.iter().zip(f.log.iter()).enumerate() {
                assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: log[{i}] time");
                assert_eq!(a.kind, b.kind, "{ctx}: log[{i}] kind");
            }
            // every metric axis, bitwise (runtime pinned so the one
            // wall-clock axis compares too)
            let mm = MetricRow::compute(&m.schedule, &prob.graphs, &prob.network, 0.0);
            let fm = MetricRow::compute(&f.schedule, &prob.graphs, &prob.network, 0.0);
            for metric in Metric::ALL {
                assert_eq!(
                    mm.get(metric).to_bits(),
                    fm.get(metric).to_bits(),
                    "{ctx}: {metric:?} diverged"
                );
            }
            // replan/revert accounting agrees as well
            assert_eq!(m.n_replans(), f.n_replans(), "{ctx}");
            assert_eq!(m.n_reverted_total(), f.n_reverted_total(), "{ctx}");
            assert!(f.admission.migrations.is_empty(), "{ctx}: S=1 migrated");
        }
    }
}

/// A 4-shard run is bit-identical at any worker count (the shard
/// fan-out uses the same deterministic work-queue discipline as the
/// sweeps).
#[test]
fn sharded_run_is_jobs_deterministic() {
    for dataset in [Dataset::Synthetic, Dataset::RiotBench] {
        let prob = dataset.instance(12, 5);
        let cfg = reactive_cfg(5, false);
        let run = |jobs: usize| {
            FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, 5, cfg, 4)
                .with_jobs(jobs)
                .run(&prob)
        };
        let base = run(1);
        for jobs in [2usize, 8] {
            let r = run(jobs);
            let ctx = format!("{} jobs={jobs}", dataset.name());
            assert_eq!(sig(&base.schedule), sig(&r.schedule), "{ctx}: schedule");
            assert_eq!(base.log, r.log, "{ctx}: log");
            assert_eq!(base.admission.shard_of, r.admission.shard_of, "{ctx}");
            assert_eq!(base.admission.migrations, r.admission.migrations, "{ctx}");
            assert_eq!(base.n_replans(), r.n_replans(), "{ctx}");
        }
    }
}

/// Admission conservation: `shard_graphs` is a partition of the graph
/// set consistent with `shard_of`, every task is realized exactly once
/// in the merged schedule (a re-executed task would double-assign and
/// panic inside the merge), and the merged schedule replays cleanly
/// against the *original* problem.
#[test]
fn admission_conserves_graphs_and_replays() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 30 + di as u64;
        let prob = dataset.instance(10, seed);
        let cfg = reactive_cfg(seed, false);
        let res = FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, seed, cfg, 3)
            .with_jobs(2)
            .run(&prob);
        let ctx = dataset.name();

        let mut owner = vec![None; prob.graphs.len()];
        for (si, graphs) in res.shard_graphs.iter().enumerate() {
            for &gi in graphs {
                assert!(owner[gi].is_none(), "{ctx}: graph {gi} on two shards");
                owner[gi] = Some(si);
                assert_eq!(res.admission.shard_of[gi], si, "{ctx}: shard_of[{gi}]");
            }
        }
        assert!(owner.iter().all(|o| o.is_some()), "{ctx}: unadmitted graph");
        for m in &res.admission.migrations {
            assert_eq!(res.admission.shard_of[m.graph], m.to, "{ctx}: stale record");
            assert_ne!(m.from, m.to, "{ctx}: self-migration");
        }

        assert_eq!(
            res.schedule.n_assigned(),
            prob.total_tasks(),
            "{ctx}: merged schedule incomplete"
        );
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            rep.errors.is_empty(),
            "{ctx}: {:?}",
            &rep.errors[..rep.errors.len().min(3)]
        );
        let cost = res.preemption_cost();
        assert_eq!(cost.migrations, res.admission.migrations.len(), "{ctx}");
    }
}

/// Frozen-prefix invariant per shard: a task that started executing
/// before a shard-local replan keeps its node and start time — both in
/// the shard's own schedule and, after index remapping, in the merged
/// global schedule.
#[test]
fn frozen_prefix_holds_per_shard() {
    let prob = Dataset::Synthetic.instance(12, 17);
    let cfg = reactive_cfg(17, true);
    let res = FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, 17, cfg, 3)
        .with_jobs(2)
        .run(&prob);
    let mut straggler_replans = 0usize;
    for (si, shard) in res.per_shard.iter().enumerate() {
        straggler_replans += shard.n_straggler_replans();
        for rec in &shard.replans {
            for &(gid, node, start) in &rec.frozen {
                let a = shard.schedule.get(gid).unwrap();
                assert_eq!(
                    (a.node, a.start.to_bits()),
                    (node, start.to_bits()),
                    "shard {si}: replan at {} moved started task {gid}",
                    rec.time
                );
                // ... and the merge preserved it in global indices
                let global = Gid::new(res.shard_graphs[si][gid.graph as usize], gid.task as usize);
                let ga = res.schedule.get(global).unwrap();
                assert_eq!(ga.node, res.shard_nodes[si][node], "merge moved {global}");
                assert_eq!(ga.start.to_bits(), start.to_bits(), "merge shifted {global}");
            }
        }
    }
    assert_eq!(
        straggler_replans,
        res.n_straggler_replans(),
        "federation sums shard-local straggler replans"
    );
}
