//! Cross-heuristic integration: relative quality and behavioural
//! signatures of the five base schedulers on static (single-arrival)
//! problems, where classic results must hold.

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::schedule::validate;
use dts::schedulers::SchedulerKind;
use dts::stats::mean;
use dts::workloads::{synthetic, Dataset};

/// A single-arrival problem: the static scheduling special case.
fn static_problem(seed: u64, n_graphs: usize) -> DynamicProblem {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let net = Network::default_eval(&mut rng);
    let graphs = synthetic::generate(n_graphs, &mut rng);
    DynamicProblem::new(net, graphs.into_iter().map(|g| (0.0, g)).collect())
}

fn makespan(kind: SchedulerKind, prob: &DynamicProblem, seed: u64) -> f64 {
    let mut c = Coordinator::new(Policy::NonPreemptive, kind.make(seed));
    let res = c.run(prob);
    let viol = validate(&res.schedule, &prob.graphs, &prob.network);
    assert!(viol.is_empty(), "{kind:?}: {viol:?}");
    res.metrics(prob).total_makespan
}

#[test]
fn heft_beats_random_on_average() {
    let mut heft = Vec::new();
    let mut random = Vec::new();
    for seed in 0..8 {
        let prob = static_problem(seed, 6);
        heft.push(makespan(SchedulerKind::Heft, &prob, seed));
        random.push(makespan(SchedulerKind::Random, &prob, seed));
    }
    assert!(
        mean(&heft) < 0.95 * mean(&random),
        "HEFT {} vs Random {}",
        mean(&heft),
        mean(&random)
    );
}

#[test]
fn informed_heuristics_beat_random_on_average() {
    for kind in [SchedulerKind::Cpop, SchedulerKind::MinMin, SchedulerKind::MaxMin] {
        let mut ours = Vec::new();
        let mut random = Vec::new();
        for seed in 0..8 {
            let prob = static_problem(seed + 100, 6);
            ours.push(makespan(kind, &prob, seed));
            random.push(makespan(SchedulerKind::Random, &prob, seed));
        }
        assert!(
            mean(&ours) < 1.05 * mean(&random),
            "{kind:?} {} should not lose badly to Random {}",
            mean(&ours),
            mean(&random)
        );
    }
}

#[test]
fn all_schedulers_valid_on_every_dataset() {
    for dataset in Dataset::ALL {
        let prob = dataset.instance(10, 31);
        for kind in SchedulerKind::ALL {
            let mut c = Coordinator::new(Policy::LastK(3), kind.make(7));
            let res = c.run(&prob);
            let viol = validate(&res.schedule, &prob.graphs, &prob.network);
            assert!(
                viol.is_empty(),
                "{kind:?} on {}: {:?}",
                dataset.name(),
                &viol[..viol.len().min(3)]
            );
        }
    }
}

#[test]
fn deterministic_given_seed() {
    for kind in SchedulerKind::ALL {
        let prob = static_problem(5, 4);
        let a = makespan(kind, &prob, 42);
        let b = makespan(kind, &prob, 42);
        assert_eq!(a, b, "{kind:?} must be deterministic");
    }
}

#[test]
fn random_scheduler_varies_with_seed() {
    let prob = static_problem(6, 4);
    let a = makespan(SchedulerKind::Random, &prob, 1);
    let b = makespan(SchedulerKind::Random, &prob, 2);
    assert_ne!(a, b);
}

#[test]
fn heft_uses_heterogeneity() {
    // one very fast node: HEFT's makespan on the heterogeneous network
    // must beat its makespan on a uniform-slow network
    let mut rng = Xoshiro256pp::seed_from_u64(8);
    let graphs = synthetic::generate(4, &mut rng);
    let slow = Network::new(vec![1.0, 1.0], vec![0.0, 1.0, 1.0, 0.0]);
    let fast = Network::new(vec![1.0, 5.0], vec![0.0, 1.0, 1.0, 0.0]);
    let mk = |net: &Network| {
        let prob = DynamicProblem::new(
            net.clone(),
            graphs.iter().cloned().map(|g| (0.0, g)).collect(),
        );
        makespan(SchedulerKind::Heft, &prob, 0)
    };
    assert!(mk(&fast) < mk(&slow));
}
