//! Experiment-harness integration: small sweeps must reproduce the
//! paper's *qualitative* claims (§VII) — who wins, in which direction —
//! on reduced instance sizes that keep CI fast.

use dts::config::ExperimentConfig;
use dts::coordinator::Variant;
use dts::experiments::run_sweep;
use dts::metrics::Metric;
use dts::workloads::Dataset;

fn cfg(dataset: Dataset, n_graphs: usize, trials: usize, labels: &[&str]) -> ExperimentConfig {
    ExperimentConfig {
        dataset,
        n_graphs,
        trials,
        seed: 1000,
        load: 0.5,
        variants: labels.iter().map(|l| Variant::parse(l).unwrap()).collect(),
    }
}

#[test]
fn adversarial_np_heft_much_worse_than_p_heft() {
    // §VII.A / Fig 8a: "NP-HEFT's makespan is 1.6× that of P-HEFT"
    let c = cfg(
        Dataset::Adversarial,
        20,
        3,
        &["P-HEFT", "NP-HEFT", "5P-HEFT", "10P-HEFT", "20P-HEFT"],
    );
    let r = run_sweep(&c);
    let p = r.value_of("P-HEFT", Metric::TotalMakespan).unwrap();
    let np = r.value_of("NP-HEFT", Metric::TotalMakespan).unwrap();
    assert!(
        np > 1.15 * p,
        "adversarial gap missing: NP {np:.3} vs P {p:.3}"
    );
    // partially preemptive close to P (within ~15%)
    for k in ["10P-HEFT", "20P-HEFT"] {
        let v = r.value_of(k, Metric::TotalMakespan).unwrap();
        assert!(
            v < 0.75 * np.max(1.3 * p),
            "{k} {v:.3} should sit near P {p:.3}, far from NP {np:.3}"
        );
    }
}

#[test]
fn adversarial_utilization_improves_with_preemption() {
    // Fig 8e: utilization rises sharply from 5P toward P
    let c = cfg(
        Dataset::Adversarial,
        20,
        3,
        &["NP-HEFT", "5P-HEFT", "P-HEFT"],
    );
    let r = run_sweep(&c);
    let np = r.value_of("NP-HEFT", Metric::Utilization).unwrap();
    let p = r.value_of("P-HEFT", Metric::Utilization).unwrap();
    assert!(p > np, "P util {p:.3} must exceed NP {np:.3}");
}

#[test]
fn flowtime_favors_np_on_regular_workloads() {
    // §VII.C / Fig 5: non-preemptive schedulers have the smallest
    // flowtime — they never spread a graph's tasks apart after placement.
    let c = cfg(Dataset::Synthetic, 24, 3, &["NP-HEFT", "P-HEFT"]);
    let r = run_sweep(&c);
    let np = r.value_of("NP-HEFT", Metric::MeanFlowtime).unwrap();
    let p = r.value_of("P-HEFT", Metric::MeanFlowtime).unwrap();
    assert!(
        np <= p * 1.05,
        "NP flowtime {np:.3} should not exceed P {p:.3}"
    );
}

#[test]
fn runtime_ordering_np_fastest_p_slowest() {
    // §VII.D / Fig 6: NP < low-K < P in scheduler runtime
    let c = cfg(Dataset::Synthetic, 30, 3, &["NP-HEFT", "2P-HEFT", "P-HEFT"]);
    let r = run_sweep(&c);
    let np = r.value_of("NP-HEFT", Metric::Runtime).unwrap();
    let p = r.value_of("P-HEFT", Metric::Runtime).unwrap();
    assert!(np < p, "NP runtime {np:.4} must beat P {p:.4}");
}

#[test]
fn total_makespan_preemption_helps_or_ties() {
    // §VII.A: preemptive schedulers generally achieve smaller makespans
    // (gap may be small on regular workloads — require no more than a
    // tiny regression).
    for dataset in [Dataset::Synthetic, Dataset::RiotBench] {
        let c = cfg(dataset, 24, 3, &["NP-HEFT", "P-HEFT"]);
        let r = run_sweep(&c);
        let np = r.value_of("NP-HEFT", Metric::TotalMakespan).unwrap();
        let p = r.value_of("P-HEFT", Metric::TotalMakespan).unwrap();
        assert!(
            p <= np * 1.05,
            "{}: P {p:.3} should not exceed NP {np:.3} by >5%",
            dataset.name()
        );
    }
}

#[test]
fn sweep_runs_on_every_dataset_with_core_grid() {
    for dataset in Dataset::ALL {
        let c = ExperimentConfig {
            dataset,
            n_graphs: 8,
            trials: 1,
            seed: 5,
            load: 0.5,
            variants: dts::experiments::core_variants(),
        };
        let r = run_sweep(&c);
        assert_eq!(r.labels.len(), 18);
        // tables render for every metric
        for m in Metric::ALL {
            let t = r.figure_table(m);
            assert!(t.contains("P-HEFT"), "{}", dataset.name());
        }
    }
}
