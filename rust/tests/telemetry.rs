//! Acceptance pins for the PR-8 telemetry subsystem
//! ([`dts::telemetry`]): the registry is an *observer*, never an
//! actor.
//!
//! * **Bit-transparency grid** — all four datasets × the controller
//!   families {`L3@0.25`, `A3-20`, `D3@0.25`} monolithic, plus the
//!   federated runtime at shards {1, 4}: realized schedules, event
//!   logs, replan records, replan-path allocation counts and all 18
//!   [`Metric::ALL`] axes (at a pinned runtime argument — wall clock is
//!   the one axis that varies by nature) are byte-identical with
//!   telemetry enabled vs disabled.
//! * **Phase reconciliation** — `refresh_s + heuristic_s + bookkeep_s`
//!   reconciles with `wall_s` per replan record and at run totals
//!   (`refresh_wall_s + sched_runtime_s + bookkeep_wall_s ≈
//!   replan_wall_s`), and `sched_runtime_s ≤ replan_wall_s` (the
//!   superset invariant of docs/METRICS.md).
//! * **Deterministic merge** — per-shard registries absorbed
//!   shard-ordered produce identical counters and identical non-wall
//!   histogram bins run-to-run *and* across worker-thread counts
//!   (serial vs parallel shard fan-out).
//!
//! Telemetry state is thread-local and the test harness runs each test
//! on its own thread, so `reset`/`set_enabled` here cannot race with
//! other tests.

use dts::coordinator::{DynamicProblem, Policy};
use dts::federation::{FederatedCoordinator, FederationResult};
use dts::graph::Gid;
use dts::metrics::{Metric, MetricRow};
use dts::policy::PolicySpec;
use dts::schedulers::SchedulerKind;
use dts::sim::{Reaction, ReactiveCoordinator, SimConfig, SimResult};
use dts::telemetry::{self, Counter, Hist};
use dts::workloads::Dataset;

/// Straggler driver: the built-in reaction or a policy-engine spec.
enum Ctl {
    Reaction(Reaction),
    Spec(PolicySpec),
}

fn l3() -> Ctl {
    Ctl::Reaction(Reaction::LastK {
        k: 3,
        threshold: 0.25,
    })
}

fn controllers() -> [(&'static str, Ctl); 3] {
    [
        ("L3@0.25", l3()),
        (
            "A3-20",
            Ctl::Spec(PolicySpec::AdaptiveK {
                k0: 3,
                k_max: 20,
                threshold: 0.25,
                target_stretch: 2.0,
            }),
        ),
        (
            "D3@0.25",
            Ctl::Spec(PolicySpec::DeadlineAware {
                k: 3,
                threshold: 0.25,
            }),
        ),
    ]
}

fn run_mono(prob: &DynamicProblem, seed: u64, noise_std: f64, ctl: &Ctl) -> SimResult {
    let mut cfg = SimConfig {
        noise_std,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::None,
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    let mut rc = match ctl {
        Ctl::Reaction(r) => {
            cfg.reaction = *r;
            ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(seed), cfg)
        }
        Ctl::Spec(spec) => ReactiveCoordinator::with_policy(
            Policy::LastK(5),
            SchedulerKind::Heft.make(seed),
            cfg,
            spec.make(),
        ),
    };
    rc.run(prob)
}

fn run_fed(prob: &DynamicProblem, seed: u64, noise_std: f64, shards: usize) -> FederationResult {
    let cfg = SimConfig {
        noise_std,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::LastK {
            k: 3,
            threshold: 0.25,
        },
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    FederatedCoordinator::new(Policy::LastK(5), SchedulerKind::Heft, seed ^ 0x5EED, cfg, shards)
        .run(prob)
}

fn sig(s: &dts::schedule::Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

/// All 18 metric axes at a pinned runtime argument, as raw bits.
fn metric_bits(s: &dts::schedule::Schedule, prob: &DynamicProblem) -> Vec<u64> {
    let row = MetricRow::compute(s, &prob.graphs, &prob.network, 0.0);
    Metric::ALL.iter().map(|m| row.get(*m).to_bits()).collect()
}

/// Work-shape signature of a replan record (everything but wall clocks).
fn replan_sig(r: &dts::sim::ReplanRecord) -> (u64, bool, usize, usize, usize) {
    (
        r.time.to_bits(),
        r.straggler,
        r.n_reverted,
        r.n_pending,
        r.n_refreshed,
    )
}

/// THE GRID, monolithic half: 4 datasets × 3 controller families, each
/// run twice — telemetry enabled (recording verified non-empty) vs
/// disabled (registry verified untouched) — with schedules, logs,
/// replan records, allocation counts and all 18 metric axes
/// byte-identical.
#[test]
fn telemetry_on_off_bit_identity_monolithic_grid() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for (ci, (name, ctl)) in controllers().iter().enumerate() {
            let seed = 4200 + 31 * di as u64 + 7 * ci as u64;
            let prob = dataset.instance(8, seed);
            let ctx = format!("{} {name}", dataset.name());

            telemetry::set_enabled(true);
            telemetry::reset();
            let on = run_mono(&prob, seed, 0.3, ctl);
            let recorded = telemetry::take();
            assert!(!recorded.is_empty(), "{ctx}: nothing recorded");
            assert_eq!(
                recorded.counter(Counter::Replans),
                on.n_replans() as u64,
                "{ctx}: replan counter disagrees with the run"
            );
            assert!(recorded.counter(Counter::EftPlacements) > 0, "{ctx}");

            telemetry::set_enabled(false);
            let off = run_mono(&prob, seed, 0.3, ctl);
            assert!(
                telemetry::snapshot().is_empty(),
                "{ctx}: disabled run still recorded"
            );
            telemetry::set_enabled(true);

            assert_eq!(sig(&on.schedule), sig(&off.schedule), "{ctx}: schedule");
            assert_eq!(on.log, off.log, "{ctx}: event log");
            assert_eq!(
                on.replans.iter().map(replan_sig).collect::<Vec<_>>(),
                off.replans.iter().map(replan_sig).collect::<Vec<_>>(),
                "{ctx}: replan records"
            );
            assert_eq!(
                on.replan_allocs, off.replan_allocs,
                "{ctx}: telemetry changed the replan-path allocation count"
            );
            assert_eq!(
                metric_bits(&on.schedule, &prob),
                metric_bits(&off.schedule, &prob),
                "{ctx}: metric axes"
            );
        }
    }
}

/// THE GRID, federated half: 4 datasets × shards {1, 4}, telemetry on
/// vs off.  The federated merge path (per-shard registries absorbed
/// shard-ordered) must be as transparent as the monolithic one, and at
/// shards > 1 the federation counters must actually fire.
#[test]
fn telemetry_on_off_bit_identity_federated_grid() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for &shards in &[1usize, 4] {
            let seed = 6100 + 17 * di as u64 + shards as u64;
            let prob = dataset.instance(8, seed);
            let ctx = format!("{} S{shards}", dataset.name());

            telemetry::set_enabled(true);
            telemetry::reset();
            let on = run_fed(&prob, seed, 0.3, shards);
            let recorded = telemetry::take();
            assert!(!recorded.is_empty(), "{ctx}: nothing recorded");
            assert_eq!(
                recorded.counter(Counter::FedAdmissions),
                prob.graphs.len() as u64,
                "{ctx}: every graph is admitted exactly once"
            );

            telemetry::set_enabled(false);
            let off = run_fed(&prob, seed, 0.3, shards);
            assert!(
                telemetry::snapshot().is_empty(),
                "{ctx}: disabled run still recorded"
            );
            telemetry::set_enabled(true);

            assert_eq!(sig(&on.schedule), sig(&off.schedule), "{ctx}: schedule");
            assert_eq!(on.log, off.log, "{ctx}: event log");
            assert_eq!(
                on.admission.shard_of, off.admission.shard_of,
                "{ctx}: admission"
            );
            assert_eq!(
                metric_bits(&on.schedule, &prob),
                metric_bits(&off.schedule, &prob),
                "{ctx}: metric axes"
            );
        }
    }
}

/// Phase decomposition adds up: per replan record the three phases
/// reconcile with the whole-pass wall time (the bookkeeping remainder
/// is *defined* as the clamped difference, so disagreement beyond f64
/// rounding means a phase was double- or un-counted), and the run-level
/// accumulators reconcile the same way.  Also pins the satellite-2
/// superset invariant `sched_runtime_s ≤ replan_wall_s` and the
/// registry's view of the same run (replan count, cone-size samples).
#[test]
fn phase_decomposition_reconciles() {
    telemetry::set_enabled(true);
    telemetry::reset();
    let prob = Dataset::Synthetic.instance(10, 77);
    let res = run_mono(&prob, 77, 0.4, &l3());
    let recorded = telemetry::take();
    assert!(res.n_replans() > 0, "scenario must replan");

    let mut sum_refresh = 0.0;
    let mut sum_heuristic = 0.0;
    let mut sum_bookkeep = 0.0;
    let mut sum_wall = 0.0;
    for (i, r) in res.replans.iter().enumerate() {
        assert!(r.refresh_s >= 0.0 && r.heuristic_s >= 0.0 && r.bookkeep_s >= 0.0);
        let sum = r.refresh_s + r.heuristic_s + r.bookkeep_s;
        assert!(
            (sum - r.wall_s).abs() <= 1e-9 + 1e-9 * r.wall_s,
            "replan {i}: phases {sum} vs wall {}",
            r.wall_s
        );
        sum_refresh += r.refresh_s;
        sum_heuristic += r.heuristic_s;
        sum_bookkeep += r.bookkeep_s;
        sum_wall += r.wall_s;
    }
    // run accumulators agree with the per-record sums...
    assert!((sum_refresh - res.refresh_wall_s).abs() <= 1e-9 + 1e-9 * sum_wall);
    assert!((sum_heuristic - res.sched_runtime_s).abs() <= 1e-9 + 1e-9 * sum_wall);
    assert!((sum_bookkeep - res.bookkeep_wall_s).abs() <= 1e-9 + 1e-9 * sum_wall);
    assert!((sum_wall - res.replan_wall_s).abs() <= 1e-9 + 1e-9 * sum_wall);
    // ...and the three phase totals reconcile with the wall total
    let total = res.refresh_wall_s + res.sched_runtime_s + res.bookkeep_wall_s;
    assert!(
        (total - res.replan_wall_s).abs() <= 1e-9 + 1e-6 * res.replan_wall_s,
        "phase totals {total} vs replan wall {}",
        res.replan_wall_s
    );
    // the superset invariant (docs/METRICS.md): the heuristic phase is
    // strictly inside the replan pass
    assert!(res.sched_runtime_s <= res.replan_wall_s + 1e-9);
    assert!(res.refresh_wall_s + res.bookkeep_wall_s <= res.replan_wall_s + 1e-9);

    // the registry observed the same run: one wall sample and one
    // cone-size sample per replan pass
    assert_eq!(recorded.counter(Counter::Replans), res.n_replans() as u64);
    assert_eq!(recorded.hist(Hist::ReplanWallNs).count, res.n_replans() as u64);
    assert_eq!(recorded.hist(Hist::ConeSize).count, res.n_replans() as u64);
    assert!(recorded.hist(Hist::EventQueueDepth).count > 0);
}

/// Satellite-2 regression: `sched_runtime_s` (base-heuristic phase) can
/// never exceed `replan_wall_s` (the whole pass it is timed inside), on
/// every dataset and on the federated runtime.
#[test]
fn sched_runtime_never_exceeds_replan_wall() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 300 + di as u64;
        let prob = dataset.instance(8, seed);
        let res = run_mono(&prob, seed, 0.3, &l3());
        assert!(
            res.sched_runtime_s <= res.replan_wall_s + 1e-9,
            "{}: sched {} > replan wall {}",
            dataset.name(),
            res.sched_runtime_s,
            res.replan_wall_s
        );
        let fed = run_fed(&prob, seed, 0.3, 2);
        assert!(
            fed.sched_runtime_s <= fed.replan_wall_s + 1e-9,
            "{} federated: sched {} > replan wall {}",
            dataset.name(),
            fed.sched_runtime_s,
            fed.replan_wall_s
        );
    }
}

/// Deterministic per-shard merge: the same federated run repeated
/// twice — and again across serial vs parallel shard fan-out — lands
/// identical counters and identical non-wall histograms (bins, counts,
/// sums).  Only the four wall-time histograms may differ run-to-run.
#[test]
fn per_shard_merge_is_deterministic() {
    let prob = Dataset::Synthetic.instance(10, 11);
    let run = |jobs: usize| {
        telemetry::set_enabled(true);
        telemetry::reset();
        let fed = FederatedCoordinator::new(
            Policy::LastK(5),
            SchedulerKind::Heft,
            11 ^ 0x5EED,
            SimConfig {
                noise_std: 0.3,
                noise_seed: 11 ^ 0xA11CE,
                reaction: Reaction::LastK {
                    k: 3,
                    threshold: 0.25,
                },
                record_frozen: false,
                full_refresh: false,
                faults: dts::sim::FaultConfig::NONE,
            },
            3,
        )
        .with_jobs(jobs);
        let _ = fed.run(&prob);
        telemetry::take()
    };
    let a = run(1);
    let b = run(1);
    let c = run(2); // worker threads; shard registries absorbed shard-ordered
    for t in [&b, &c] {
        for cnt in Counter::ALL {
            assert_eq!(
                a.counter(cnt),
                t.counter(cnt),
                "counter {} not deterministic",
                cnt.key()
            );
        }
        for h in Hist::ALL {
            if h.is_wall() {
                continue;
            }
            assert_eq!(a.hist(h).bins, t.hist(h).bins, "hist {} bins", h.key());
            assert_eq!(a.hist(h).count, t.hist(h).count, "hist {} count", h.key());
            assert_eq!(a.hist(h).sum, t.hist(h).sum, "hist {} sum", h.key());
        }
    }
    assert!(a.counter(Counter::FedAdmissions) > 0);
    assert!(a.counter(Counter::TxnBegin) > 0);
    assert_eq!(
        a.counter(Counter::TxnBegin),
        a.counter(Counter::TxnCommit) + a.counter(Counter::TxnRollback),
        "every journal transaction either commits or rolls back"
    );
}

/// The Prometheus-style exposition renders a merged federated registry
/// with every key present — the scrape surface stays in lockstep with
/// the enum registry.
#[test]
fn render_text_covers_every_key_after_federated_run() {
    telemetry::set_enabled(true);
    telemetry::reset();
    let prob = Dataset::Synthetic.instance(8, 13);
    let _ = run_fed(&prob, 13, 0.3, 2);
    let text = telemetry::take().render_text();
    for c in Counter::ALL {
        assert!(text.contains(&format!("dts_{}", c.key())), "{}", c.key());
    }
    for h in Hist::ALL {
        assert!(text.contains(&format!("dts_{}_count", h.key())), "{}", h.key());
        assert!(
            text.contains(&format!("dts_{}_bucket{{le=\"+Inf\"}}", h.key())),
            "{}",
            h.key()
        );
    }
}
