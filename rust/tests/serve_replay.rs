//! Replay bit-identity: the streaming daemon is *the offline sim with a
//! wire protocol*.  Feeding a recorded cell through [`ServeServer`] —
//! either as individual `arrive` ops or as the whole recorded
//! `dts-sim-trace-v1` document — and closing the epoch reproduces the
//! offline run **bit-exactly**:
//!
//! * the decision stream equals the offline event log line-for-line
//!   (both sides serialize through [`dts::trace::sim_event_json`]);
//! * the epoch summary's 18-metric block equals the offline
//!   [`metric_row_json`] to the bit;
//! * replan counts and revert totals agree.
//!
//! The grid covers every dataset, monolithic and federated (`--shards
//! 4`), and federated at `--jobs 1` vs `--jobs 2` (shard fan-out must
//! not leak into the stream).  This is the same invariant the CI
//! `serve-smoke` job checks end-to-end with `cmp` over the real binary.

use dts::coordinator::Variant;
use dts::experiments::metric_row_json;
use dts::federation::FederatedCoordinator;
use dts::json::Value;
use dts::serve::{Controller, ServeConfig, ServeServer};
use dts::sim::{Reaction, ReactiveCoordinator, SimConfig};
use dts::trace::{sim_event_json, sim_to_json};
use dts::workloads::{Dataset, Scenario, DEFAULT_LOAD};

const SEED: u64 = 11;
const GRAPHS: usize = 6;

fn serve_cfg(dataset: Dataset, shards: usize, jobs: usize) -> ServeConfig {
    ServeConfig {
        dataset,
        n_graphs: GRAPHS,
        seed: SEED,
        variant: Variant::parse("5P-HEFT").unwrap(),
        noise_std: 0.3,
        controller: Controller::Reaction(Reaction::LastK {
            k: 3,
            threshold: 0.25,
        }),
        shards,
        jobs,
        load: DEFAULT_LOAD,
        scenario: Scenario::default(),
        faults: dts::sim::FaultConfig::NONE,
    }
}

fn sim_cfg() -> SimConfig {
    SimConfig {
        noise_std: 0.3,
        noise_seed: SEED ^ 0xA11CE,
        reaction: Reaction::LastK {
            k: 3,
            threshold: 0.25,
        },
        record_frozen: false,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    }
}

/// The offline cell: event lines (serialized exactly as the trace
/// exporter does) + the 18-metric block as a parsed JSON value.
fn offline(dataset: Dataset, shards: usize, jobs: usize) -> (Vec<String>, Value, usize) {
    let prob = dataset.instance_scenario(GRAPHS, SEED, DEFAULT_LOAD, None, &Scenario::default());
    let variant = Variant::parse("5P-HEFT").unwrap();
    if shards > 1 {
        let fed = FederatedCoordinator::new(
            variant.policy,
            variant.kind,
            SEED ^ 0x5EED,
            sim_cfg(),
            shards,
        )
        .with_jobs(jobs);
        let res = fed.run(&prob);
        let events = res.log.iter().map(|e| sim_event_json(e).to_string()).collect();
        let metrics =
            Value::from_str(&metric_row_json(&res.metrics(&prob)).to_string()).unwrap();
        (events, metrics, res.n_replans())
    } else {
        let mut rc = ReactiveCoordinator::new(
            variant.policy,
            variant.kind.make(SEED ^ 0x5EED),
            sim_cfg(),
        );
        let res = rc.run(&prob);
        let events = res.log.iter().map(|e| sim_event_json(e).to_string()).collect();
        let metrics =
            Value::from_str(&metric_row_json(&res.metrics(&prob)).to_string()).unwrap();
        (events, metrics, res.n_replans())
    }
}

/// Filter the serve output down to the decision stream.
fn decision_lines(out: &[String]) -> Vec<String> {
    out.iter()
        .filter(|l| {
            let v = Value::from_str(l).unwrap();
            matches!(
                v.get("kind").and_then(|k| k.as_str()),
                Some("arrival") | Some("start") | Some("finish") | Some("replan")
            )
        })
        .cloned()
        .collect()
}

fn summary_of(out: &[String]) -> Value {
    let line = out
        .iter()
        .find(|l| l.contains("\"kind\":\"summary\""))
        .expect("no summary line");
    Value::from_str(line).unwrap()
}

/// Feed the full instance as `arrive` ops + `run`, return the output.
fn serve_full_session(cfg: ServeConfig) -> Vec<String> {
    let mut server = ServeServer::new(cfg);
    let mut out = Vec::new();
    for g in 0..GRAPHS {
        server.handle_line(&format!("{{\"op\":\"arrive\",\"graph\":{g}}}"), &mut out);
    }
    server.handle_line("{\"op\":\"run\"}", &mut out);
    out
}

fn assert_replay(dataset: Dataset, shards: usize, jobs: usize) {
    let (events, metrics, n_replans) = offline(dataset, shards, jobs);
    let out = serve_full_session(serve_cfg(dataset, shards, jobs));
    let got = decision_lines(&out);
    assert_eq!(
        got.len(),
        events.len(),
        "{} S{shards} j{jobs}: decision-line count",
        dataset.name()
    );
    for (i, (g, e)) in got.iter().zip(&events).enumerate() {
        assert_eq!(g, e, "{} S{shards} j{jobs}: event {i}", dataset.name());
    }
    let summary = summary_of(&out);
    assert_eq!(
        summary.get("metrics").unwrap(),
        &metrics,
        "{} S{shards} j{jobs}: 18-metric block",
        dataset.name()
    );
    assert_eq!(
        summary.get("n_replans").and_then(|x| x.as_usize()),
        Some(n_replans),
        "{} S{shards} j{jobs}: replan count",
        dataset.name()
    );
}

#[test]
fn replay_monolithic_all_datasets() {
    for d in Dataset::ALL {
        assert_replay(d, 1, 1);
    }
}

#[test]
fn replay_federated_all_datasets() {
    for d in Dataset::ALL {
        assert_replay(d, 4, 1);
    }
}

#[test]
fn replay_federated_jobs_independent() {
    // --jobs only fans shard work over threads; the stream is pinned
    // identical at any value
    for d in Dataset::ALL {
        let one = serve_full_session(serve_cfg(d, 4, 1));
        let two = serve_full_session(serve_cfg(d, 4, 2));
        assert_eq!(one, two, "{}: jobs 1 vs 2", d.name());
    }
}

#[test]
fn trace_document_feed_replays_bit_exactly() {
    // the CI path: record the offline trace, feed the whole document as
    // one request line, run — the decision stream must equal the
    // document's own events array, entry for entry (print ∘ parse is
    // idempotent, so string equality IS byte equality)
    for d in Dataset::ALL {
        let prob = d.instance_scenario(GRAPHS, SEED, DEFAULT_LOAD, None, &Scenario::default());
        let variant = Variant::parse("5P-HEFT").unwrap();
        let mut rc = ReactiveCoordinator::new(
            variant.policy,
            variant.kind.make(SEED ^ 0x5EED),
            sim_cfg(),
        );
        let res = rc.run(&prob);
        let doc = sim_to_json(&prob, &res).to_string();
        assert!(!doc.contains('\n'), "trace document must be one line");

        let mut server = ServeServer::new(serve_cfg(d, 1, 1));
        let mut out = Vec::new();
        server.handle_line(&doc, &mut out);
        assert!(
            out[0].contains("\"kind\":\"ack\"") && out[0].contains("\"admitted\":6"),
            "{}: trace ack, got {}",
            d.name(),
            out[0]
        );
        server.handle_line("{\"op\":\"run\"}", &mut out);

        let reparsed = Value::from_str(&doc).unwrap();
        let want: Vec<String> = reparsed
            .get("events")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|e| e.to_string())
            .collect();
        assert_eq!(decision_lines(&out), want, "{}", d.name());
    }
}

#[test]
fn trace_feed_rejects_foreign_instance() {
    // a trace recorded under a different seed describes a different
    // instance: the server must refuse it wholesale and stay pristine
    let d = Dataset::Synthetic;
    let prob = d.instance_scenario(GRAPHS, 99, DEFAULT_LOAD, None, &Scenario::default());
    let variant = Variant::parse("5P-HEFT").unwrap();
    let mut rc = ReactiveCoordinator::new(
        variant.policy,
        variant.kind.make(99 ^ 0x5EED),
        SimConfig {
            noise_seed: 99 ^ 0xA11CE,
            ..sim_cfg()
        },
    );
    let res = rc.run(&prob);
    let doc = sim_to_json(&prob, &res).to_string();

    let mut server = ServeServer::new(serve_cfg(d, 1, 1));
    let mut out = Vec::new();
    server.handle_line(&doc, &mut out);
    assert_eq!(out.len(), 1);
    assert!(out[0].contains("\"kind\":\"error\""), "{}", out[0]);
    assert!(out[0].contains("\"code\":\"trace\""), "{}", out[0]);
    assert!(server.pending().is_empty());
}

#[test]
fn partial_epochs_compose_the_full_graph_set() {
    // splitting the instance across two epochs is NOT the offline run
    // (each epoch is its own virtual-clock world) but must cover every
    // graph exactly once and produce one summary per epoch
    let mut server = ServeServer::new(serve_cfg(Dataset::Synthetic, 1, 1));
    let mut out = Vec::new();
    for g in [0usize, 2, 4] {
        server.handle_line(&format!("{{\"op\":\"arrive\",\"graph\":{g}}}"), &mut out);
    }
    server.handle_line("{\"op\":\"run\"}", &mut out);
    for g in [1usize, 3, 5] {
        server.handle_line(&format!("{{\"op\":\"arrive\",\"graph\":{g}}}"), &mut out);
    }
    server.handle_line("{\"op\":\"run\"}", &mut out);
    assert_eq!(server.epochs().len(), 2);
    assert_eq!(server.epochs()[0], vec![0, 2, 4]);
    assert_eq!(server.epochs()[1], vec![1, 3, 5]);
    let summaries: Vec<&String> = out
        .iter()
        .filter(|l| l.contains("\"kind\":\"summary\""))
        .collect();
    assert_eq!(summaries.len(), 2);
    // epoch decision lines carry the client's global graph ids
    let second_epoch_graphs: Vec<usize> = Value::from_str(summaries[1])
        .unwrap()
        .get("graphs")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(second_epoch_graphs, vec![1, 3, 5]);
}
