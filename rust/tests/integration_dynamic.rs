//! End-to-end dynamic coordinator integration: every (dataset × policy ×
//! heuristic) combination must produce §II-valid, replay-consistent
//! schedules, and the preemption machinery must behave per the paper's
//! model.

use dts::coordinator::{paper_grid, Coordinator, DynamicProblem, Policy, Variant};
use dts::schedule::validate;
use dts::schedulers::SchedulerKind;
use dts::sim::replay;
use dts::workloads::Dataset;

fn check(prob: &DynamicProblem, variant: Variant, seed: u64) {
    let mut c = variant.coordinator(seed);
    let res = c.run(prob);
    assert_eq!(
        res.schedule.n_assigned(),
        prob.total_tasks(),
        "{} left tasks unscheduled",
        variant.label()
    );
    let viol = validate(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        viol.is_empty(),
        "{}: {:?}",
        variant.label(),
        &viol[..viol.len().min(3)]
    );
    let rep = replay(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        rep.errors.is_empty(),
        "{}: {:?}",
        variant.label(),
        &rep.errors[..rep.errors.len().min(3)]
    );
}

#[test]
fn full_grid_on_synthetic() {
    let prob = Dataset::Synthetic.instance(14, 100);
    for v in paper_grid() {
        check(&prob, v, 1);
    }
}

#[test]
fn full_grid_on_adversarial() {
    let prob = Dataset::Adversarial.instance(10, 200);
    for v in paper_grid() {
        check(&prob, v, 2);
    }
}

#[test]
fn key_variants_on_riotbench_and_wfcommons() {
    for dataset in [Dataset::RiotBench, Dataset::WfCommons] {
        let prob = dataset.instance(12, 300);
        for label in ["P-HEFT", "NP-HEFT", "5P-CPOP", "2P-MinMin", "20P-MaxMin", "P-Random"] {
            check(&prob, Variant::parse(label).unwrap(), 3);
        }
    }
}

#[test]
fn reverted_counts_ordered_by_policy() {
    // more preemption ⇒ at least as many reverted tasks, per event
    let prob = Dataset::Synthetic.instance(20, 5);
    let run = |policy| {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        c.run(&prob)
            .events
            .iter()
            .map(|e| e.n_reverted)
            .sum::<usize>()
    };
    let np = run(Policy::NonPreemptive);
    let k2 = run(Policy::LastK(2));
    let p = run(Policy::Preemptive);
    assert_eq!(np, 0, "NP reverts nothing");
    assert!(k2 <= p, "Last-2 ({k2}) cannot revert more than P ({p})");
    assert!(p > 0, "P should revert something on an overlapping workload");
}

#[test]
fn np_runtime_not_slower_than_p() {
    // §VII.D: non-preemptive schedulers are fastest — they solve smaller
    // composite problems.  Compare *pending work*, which is deterministic
    // (wall time on shared CI is noisy).
    let prob = Dataset::Synthetic.instance(30, 8);
    let pending = |policy| {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        c.run(&prob)
            .events
            .iter()
            .map(|e| e.n_pending)
            .sum::<usize>()
    };
    let np = pending(Policy::NonPreemptive);
    let k5 = pending(Policy::LastK(5));
    let p = pending(Policy::Preemptive);
    assert!(np <= k5, "NP pending {np} vs 5P {k5}");
    assert!(k5 <= p, "5P pending {k5} vs P {p}");
}

#[test]
fn single_graph_problem_identical_across_policies() {
    // with one graph there is nothing to preempt: all policies agree
    let prob = Dataset::RiotBench.instance(1, 9);
    let sig = |policy: Policy| {
        let mut c = Coordinator::new(policy, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        let mut v: Vec<_> = res
            .schedule
            .iter()
            .map(|(g, a)| (*g, a.node, a.start.to_bits()))
            .collect();
        v.sort();
        v
    };
    let p = sig(Policy::Preemptive);
    assert_eq!(p, sig(Policy::NonPreemptive));
    assert_eq!(p, sig(Policy::LastK(3)));
}

#[test]
fn far_apart_arrivals_make_policies_agree() {
    // if every graph finishes before the next arrives, preemption never
    // fires: P ≡ NP
    use dts::network::Network;
    use dts::prng::Xoshiro256pp;
    use dts::workloads::synthetic;
    let mut rng = Xoshiro256pp::seed_from_u64(17);
    let graphs = synthetic::generate(6, &mut rng);
    // arrivals far beyond any plausible makespan
    let problem = DynamicProblem::new(
        Network::homogeneous(4),
        graphs
            .into_iter()
            .enumerate()
            .map(|(i, g)| (i as f64 * 1e6, g))
            .collect(),
    );
    let run = |policy: Policy| {
        let mut c = Coordinator::new(policy, SchedulerKind::Cpop.make(0));
        let res = c.run(&problem);
        res.metrics(&problem).total_makespan
    };
    let p = run(Policy::Preemptive);
    let np = run(Policy::NonPreemptive);
    assert!((p - np).abs() < 1e-9, "P {p} vs NP {np}");
}
