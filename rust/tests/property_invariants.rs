//! Property-based tests (seeded randomized sweeps — the offline crate set
//! has no proptest, so we drive our own generator loop): the §II
//! invariants and the coordinator's preemption laws over hundreds of
//! random instances.

use dts::coordinator::{Coordinator, DynamicProblem, Policy};
use dts::graph::{Gid, GraphBuilder, TaskGraph};
use dts::network::Network;
use dts::prng::Xoshiro256pp;
use dts::schedule::{validate, EPS};
use dts::schedulers::SchedulerKind;
use dts::sim::replay;
use dts::stats::TruncatedGaussian;

/// Random DAG with edge probability `p`.
fn random_dag(rng: &mut Xoshiro256pp, n: usize, p: f64) -> TaskGraph {
    let mut b = GraphBuilder::new("prop");
    let ids: Vec<_> = (0..n).map(|_| b.task(rng.uniform(0.5, 20.0))).collect();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.next_f64() < p {
                b.edge(ids[i], ids[j], rng.uniform(0.0, 10.0));
            }
        }
    }
    b.build().unwrap()
}

/// Random dynamic instance.
fn random_instance(seed: u64) -> DynamicProblem {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n_nodes = rng.int_range(2, 6);
    let d = TruncatedGaussian::new(1.0, 0.4, 0.3, 2.5);
    let net = Network::generate(n_nodes, &d, &d, &mut rng);
    let n_graphs = rng.int_range(2, 8);
    let mut t = 0.0;
    let graphs: Vec<(f64, TaskGraph)> = (0..n_graphs)
        .map(|_| {
            let n = rng.int_range(2, 12);
            let p = rng.uniform(0.05, 0.5);
            let g = random_dag(&mut rng, n, p);
            let arr = t;
            t += rng.exponential(0.15);
            (arr, g)
        })
        .collect();
    DynamicProblem::new(net, graphs)
}

fn random_policy(rng: &mut Xoshiro256pp) -> Policy {
    match rng.below(3) {
        0 => Policy::NonPreemptive,
        1 => Policy::Preemptive,
        _ => Policy::LastK(rng.int_range(1, 6)),
    }
}

fn random_kind(rng: &mut Xoshiro256pp) -> SchedulerKind {
    SchedulerKind::ALL[rng.below(SchedulerKind::ALL.len())]
}

/// PROPERTY: every run yields a complete, §II-valid, replay-consistent
/// schedule, for random policies × heuristics × instances.
#[test]
fn prop_validity_under_random_everything() {
    let mut meta = Xoshiro256pp::seed_from_u64(0xABCDEF);
    for case in 0..150 {
        let prob = random_instance(meta.next_u64());
        let policy = random_policy(&mut meta);
        let kind = random_kind(&mut meta);
        let mut c = Coordinator::new(policy, kind.make(meta.next_u64()));
        let res = c.run(&prob);
        assert_eq!(
            res.schedule.n_assigned(),
            prob.total_tasks(),
            "case {case} {policy:?} {kind:?}"
        );
        let viol = validate(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            viol.is_empty(),
            "case {case} {policy:?} {kind:?}: {:?}",
            &viol[..viol.len().min(3)]
        );
        let rep = replay(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            rep.errors.is_empty(),
            "case {case} {policy:?} {kind:?}: {:?}",
            &rep.errors[..rep.errors.len().min(3)]
        );
    }
}

/// PROPERTY: `LastK(0)` ≡ `NonPreemptive` and `LastK(∞)` ≡ `Preemptive`
/// — exact schedule equality (deterministic heuristics only).
#[test]
fn prop_lastk_boundary_equalities() {
    let mut meta = Xoshiro256pp::seed_from_u64(0x1234);
    for _ in 0..40 {
        let prob = random_instance(meta.next_u64());
        let kind = match meta.below(4) {
            0 => SchedulerKind::Heft,
            1 => SchedulerKind::Cpop,
            2 => SchedulerKind::MinMin,
            _ => SchedulerKind::MaxMin,
        };
        let sig = |policy: Policy| {
            let mut c = Coordinator::new(policy, kind.make(0));
            let res = c.run(&prob);
            let mut v: Vec<_> = res
                .schedule
                .iter()
                .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
                .collect();
            v.sort();
            v
        };
        assert_eq!(
            sig(Policy::LastK(0)),
            sig(Policy::NonPreemptive),
            "{kind:?}"
        );
        assert_eq!(
            sig(Policy::LastK(1_000_000)),
            sig(Policy::Preemptive),
            "{kind:?}"
        );
    }
}

/// PROPERTY: commitment closure — for every edge, the parent finishes
/// (plus transfer) before the child starts, and committed tasks are never
/// moved by later arrivals.
#[test]
fn prop_committed_tasks_are_never_moved() {
    let mut meta = Xoshiro256pp::seed_from_u64(0x77);
    for _ in 0..40 {
        let prob = random_instance(meta.next_u64());
        let kind = random_kind(&mut meta);
        // run twice: once on the full problem, once on a prefix; every
        // task that started before the (k+1)-th arrival in the prefix run
        // must be identically placed in the full run under NP.
        let k = prob.graphs.len() / 2;
        if k == 0 {
            continue;
        }
        let prefix = DynamicProblem::new(prob.network.clone(), prob.graphs[..k].to_vec());
        let mut c1 = Coordinator::new(Policy::NonPreemptive, kind.make(9));
        let r_prefix = c1.run(&prefix);
        let mut c2 = Coordinator::new(Policy::NonPreemptive, kind.make(9));
        let r_full = c2.run(&prob);
        for (gid, a) in r_prefix.schedule.iter() {
            let b = r_full.schedule.get(*gid).unwrap();
            assert_eq!(a, b, "NP moved {gid}");
        }
    }
}

/// PROPERTY: under any policy, tasks that had already *started* at the
/// time of a later arrival keep their placement (verified via the event
/// trace: reverted counts exclude started tasks, and final starts of
/// early-started tasks precede the arrivals that followed them).
#[test]
fn prop_started_tasks_respect_their_commitment() {
    let mut meta = Xoshiro256pp::seed_from_u64(0x99);
    for _ in 0..40 {
        let prob = random_instance(meta.next_u64());
        let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Heft.make(0));
        let res = c.run(&prob);
        // for every graph j and later arrival a_i: if a task of j starts
        // before a_i in the FINAL schedule, then its whole dependency
        // prefix does too (closure), and it never starts inside another
        // task's interval (validated globally elsewhere).
        for (j, (_, g)) in prob.graphs.iter().enumerate() {
            for t in 0..g.n_tasks() {
                let at = res.schedule.get(Gid::new(j, t)).unwrap();
                for &(p, _) in g.predecessors(t) {
                    let ap = res.schedule.get(Gid::new(j, p)).unwrap();
                    assert!(ap.start <= at.start + EPS);
                    assert!(ap.finish <= at.start + EPS);
                }
            }
        }
    }
}

/// PROPERTY: metrics are internally consistent — mean flowtime ≤ mean
/// makespan (starts can't precede arrivals), utilization in (0, 1],
/// total makespan ≥ every per-graph response.
#[test]
fn prop_metric_consistency() {
    let mut meta = Xoshiro256pp::seed_from_u64(0xFEED);
    for _ in 0..60 {
        let prob = random_instance(meta.next_u64());
        let policy = random_policy(&mut meta);
        let kind = random_kind(&mut meta);
        let mut c = Coordinator::new(policy, kind.make(1));
        let res = c.run(&prob);
        let m = res.metrics(&prob);
        assert!(
            m.mean_flowtime <= m.mean_makespan + EPS,
            "flowtime {} > mean makespan {}",
            m.mean_flowtime,
            m.mean_makespan
        );
        assert!(m.mean_utilization > 0.0 && m.mean_utilization <= 1.0 + EPS);
        assert!(m.total_makespan + EPS >= m.mean_makespan);
        assert!(m.runtime_s >= 0.0);
    }
}
