//! Property-based validity harness for the **reactive runtime
//! simulator**: a seeded grid of (dataset × policy × noise × reaction)
//! trials over all four datasets, each asserting
//!
//! * completeness — every task of the workload is realized;
//! * operational §II validity — [`dts::sim::replay`] reports zero
//!   errors (the replay never assumes a task's duration equals its cost
//!   estimate, so it is the right oracle for noisy realized schedules);
//! * full §II validity via [`dts::schedule::validate`] at zero noise,
//!   where realized durations must equal the estimates exactly;
//! * the **frozen-prefix invariant** — a task that started executing
//!   before a replan (arrival-time or straggler-triggered Last-K) keeps
//!   its node and start time in the final realized schedule;
//! * the same properties **under fault injection** — Crash and Degrade
//!   models across the controller families, including graph-granular
//!   revert accounting for failure-forced replans and the fault-aware
//!   frozen-prefix invariant: a dispatched task keeps its placement
//!   unless a crash killed that very attempt (the only event allowed to
//!   move started work).

use dts::coordinator::Policy;
use dts::policy::PolicySpec;
use dts::schedule::validate;
use dts::schedulers::SchedulerKind;
use dts::sim::{replay, Reaction, ReactiveCoordinator, SimConfig, SimResult};
use dts::workloads::{ArrivalModel, Dataset, DeadlineModel, Scenario, WeightModel, DEFAULT_LOAD};

fn check_run(res: &SimResult, prob: &dts::coordinator::DynamicProblem, zero_noise: bool, ctx: &str) {
    assert_eq!(
        res.schedule.n_assigned(),
        prob.total_tasks(),
        "{ctx}: incomplete realized schedule"
    );
    let rep = replay(&res.schedule, &prob.graphs, &prob.network);
    assert!(
        rep.errors.is_empty(),
        "{ctx}: {:?}",
        &rep.errors[..rep.errors.len().min(3)]
    );
    if zero_noise {
        let viol = validate(&res.schedule, &prob.graphs, &prob.network);
        assert!(
            viol.is_empty(),
            "{ctx}: {:?}",
            &viol[..viol.len().min(3)]
        );
    }
    // frozen-prefix invariant, from the per-replan dispatched snapshots
    for rec in &res.replans {
        for &(gid, node, start) in &rec.frozen {
            let a = res.schedule.get(gid).unwrap();
            assert_eq!(
                (a.node, a.start.to_bits()),
                (node, start.to_bits()),
                "{ctx}: replan at {} moved started task {gid}",
                rec.time
            );
        }
    }
}

/// PROPERTY GRID: dataset × policy × noise × reaction, HEFT base.
#[test]
fn prop_reactive_validity_grid() {
    let policies = [Policy::NonPreemptive, Policy::LastK(3), Policy::Preemptive];
    let noises = [0.0, 0.35];
    let reactions = [
        Reaction::None,
        Reaction::LastK {
            k: 2,
            threshold: 0.2,
        },
    ];
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for (pi, &policy) in policies.iter().enumerate() {
            for &noise_std in &noises {
                for &reaction in &reactions {
                    let seed = 1000 + 97 * di as u64 + 17 * pi as u64;
                    let prob = dataset.instance(8, seed);
                    let cfg = SimConfig {
                        noise_std,
                        noise_seed: seed ^ 0xBEEF,
                        reaction,
                        record_frozen: true,
                        full_refresh: false,
                        faults: dts::sim::FaultConfig::NONE,
                    };
                    let mut rc = ReactiveCoordinator::new(
                        policy,
                        SchedulerKind::Heft.make(seed),
                        cfg,
                    );
                    let res = rc.run(&prob);
                    let ctx = format!(
                        "{} {policy:?} σ{noise_std} {reaction:?}",
                        dataset.name()
                    );
                    check_run(&res, &prob, noise_std == 0.0, &ctx);
                }
            }
        }
    }
}

/// The same properties across the remaining base heuristics (one noisy
/// reactive configuration each, all datasets).
#[test]
fn prop_reactive_validity_other_heuristics() {
    let kinds = [
        SchedulerKind::Cpop,
        SchedulerKind::MinMin,
        SchedulerKind::MaxMin,
        SchedulerKind::Random,
    ];
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for (ki, kind) in kinds.iter().enumerate() {
            let seed = 4000 + 31 * di as u64 + 7 * ki as u64;
            let prob = dataset.instance(6, seed);
            let cfg = SimConfig {
                noise_std: 0.4,
                noise_seed: seed ^ 0xF00D,
                reaction: Reaction::LastK {
                    k: 3,
                    threshold: 0.15,
                },
                record_frozen: true,
                full_refresh: false,
                faults: dts::sim::FaultConfig::NONE,
            };
            let mut rc = ReactiveCoordinator::new(Policy::LastK(2), kind.make(seed), cfg);
            let res = rc.run(&prob);
            let ctx = format!("{} {} reactive", dataset.name(), kind.name());
            check_run(&res, &prob, false, &ctx);
        }
    }
}

/// The same properties for the deadline scenario axis: all four
/// datasets under heavy-tail weights, critical-path×slack deadlines and
/// bursty arrivals, driven by the urgency-scoped [`dts::policy::DeadlineAware`]
/// controller.  Asserts completeness, operational §II validity, the
/// frozen-prefix invariant, and the graph-granular revert accounting
/// (straggler replans re-place exactly what they reverted).
#[test]
fn prop_deadline_aware_validity_grid() {
    let scen = Scenario {
        weights: WeightModel::HeavyTail { alpha: 1.5 },
        deadlines: DeadlineModel::CritPathSlack { slack: 1.5 },
        arrivals: ArrivalModel::Bursty { burst: 3 },
    };
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 7000 + 13 * di as u64;
        let prob = dataset.instance_scenario(9, seed, DEFAULT_LOAD, None, &scen);
        assert!(prob.graphs.iter().all(|(_, g)| g.deadline().is_some()));
        let cfg = SimConfig {
            noise_std: 0.45,
            noise_seed: seed ^ 0xDEAD,
            reaction: Reaction::None,
            record_frozen: true,
            full_refresh: false,
            faults: dts::sim::FaultConfig::NONE,
        };
        let spec = PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.1,
        };
        let mut rc = ReactiveCoordinator::with_policy(
            Policy::LastK(3),
            SchedulerKind::Heft.make(seed),
            cfg,
            spec.make(),
        );
        let res = rc.run(&prob);
        let ctx = format!("{} deadline-aware", dataset.name());
        check_run(&res, &prob, false, &ctx);
        // graph-granular revert accounting, shared with the budget path
        for rec in &res.replans {
            if rec.straggler {
                assert_eq!(rec.n_pending, rec.n_reverted, "{ctx} at {}", rec.time);
                assert!(rec.n_reverted > 0, "{ctx}: empty straggler replan recorded");
            } else {
                assert!(rec.n_pending >= rec.n_reverted, "{ctx}");
            }
        }
    }
}

/// PROPERTY GRID UNDER FAULTS: {Crash, Degrade} × controller families
/// × all four datasets.  Each cell asserts completeness, operational
/// §II validity of the realized schedule, graph-granular revert
/// accounting (every straggler-side replan — failure-forced ones
/// included — re-places exactly what it reverted), causality of
/// re-execution (a killed attempt has a strictly later realized
/// start), and the fault-aware frozen-prefix invariant: a frozen
/// (dispatched) task keeps its node and start in the final schedule
/// unless a crash killed that very attempt at or after the snapshot.
#[test]
fn prop_fault_validity_grid() {
    use dts::sim::{FaultConfig, FaultModel, SimLogKind};

    let scen = Scenario {
        weights: WeightModel::HeavyTail { alpha: 1.5 },
        deadlines: DeadlineModel::CritPathSlack { slack: 1.5 },
        arrivals: ArrivalModel::Bursty { burst: 3 },
    };
    let specs = [
        PolicySpec::FixedLastK {
            k: 3,
            threshold: 0.25,
        },
        PolicySpec::DeadlineAware {
            k: 3,
            threshold: 0.25,
        },
        PolicySpec::Budgeted {
            k: 3,
            threshold: 0.25,
            rate: 2.0,
            burst: 8.0,
        },
        PolicySpec::FailureAware {
            k: 3,
            threshold: 0.25,
        },
    ];
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for (si, spec) in specs.iter().enumerate() {
            let seed = 9000 + 41 * di as u64 + 11 * si as u64;
            // DeadlineAware conditions on deadlines; give every cell
            // the deadline scenario so all controllers see one grid
            let prob = dataset.instance_scenario(8, seed, DEFAULT_LOAD, None, &scen);
            let run = |faults: FaultConfig| {
                let cfg = SimConfig {
                    noise_std: 0.35,
                    noise_seed: seed ^ 0xBEEF,
                    reaction: Reaction::None,
                    record_frozen: true,
                    full_refresh: false,
                    faults,
                };
                ReactiveCoordinator::with_policy(
                    Policy::LastK(3),
                    SchedulerKind::Heft.make(seed),
                    cfg,
                    spec.make(),
                )
                .run(&prob)
            };
            // scale fault cycles off the faultless horizon so several
            // windows land inside it on every dataset's time units
            let base = run(FaultConfig::NONE);
            let horizon = base
                .schedule
                .iter()
                .map(|(_, a)| a.finish)
                .fold(0.0, f64::max);
            let models = [
                FaultModel::Crash {
                    mtbf: horizon / 8.0,
                    mttr: horizon / 40.0,
                },
                FaultModel::Degrade {
                    factor: 2.0,
                    span: horizon / 6.0,
                },
            ];
            for model in models {
                let res = run(FaultConfig {
                    model,
                    seed: seed ^ 0xFA17,
                    node_base: 0,
                });
                let ctx = format!("{} {} {:?}", dataset.name(), spec.label(), model);

                // completeness + operational validity
                assert_eq!(
                    res.schedule.n_assigned(),
                    prob.total_tasks(),
                    "{ctx}: incomplete realized schedule"
                );
                let rep = replay(&res.schedule, &prob.graphs, &prob.network);
                assert!(
                    rep.errors.is_empty(),
                    "{ctx}: {:?}",
                    &rep.errors[..rep.errors.len().min(3)]
                );

                // kill causality: every killed attempt re-starts
                // strictly later, and kills force a failure replan
                let mut kills: Vec<(f64, dts::graph::Gid)> = Vec::new();
                for e in &res.log {
                    if let SimLogKind::Kill { gid, .. } = e.kind {
                        kills.push((e.time, gid));
                    }
                }
                for &(t_kill, gid) in &kills {
                    let restarted = res.log.iter().any(|e| {
                        e.time >= t_kill
                            && matches!(e.kind, SimLogKind::Start { gid: g, .. } if g == gid)
                    });
                    assert!(restarted, "{ctx}: {gid:?} killed at {t_kill} never re-ran");
                }
                if !kills.is_empty() {
                    assert!(res.n_failure_replans() > 0, "{ctx}: kills without replans");
                }
                if matches!(model, FaultModel::Degrade { .. }) {
                    assert!(kills.is_empty(), "{ctx}: degrade killed a task");
                    assert_eq!(res.n_failure_replans(), 0, "{ctx}");
                }

                // graph-granular revert accounting, failure replans
                // included (they are straggler-side: reactive)
                for rec in &res.replans {
                    if rec.straggler {
                        assert_eq!(
                            rec.n_pending, rec.n_reverted,
                            "{ctx} at {}: straggler-side replan re-placed extra work",
                            rec.time
                        );
                        assert!(rec.n_reverted > 0, "{ctx}: empty replan recorded");
                    } else {
                        assert!(rec.n_pending >= rec.n_reverted, "{ctx}");
                        assert!(!rec.failure, "{ctx}: arrival replan marked failure");
                    }
                }

                // fault-aware frozen prefix: a frozen placement may
                // only change if that attempt was killed at or after
                // the snapshot instant
                for rec in &res.replans {
                    for &(gid, node, start) in &rec.frozen {
                        let a = res.schedule.get(gid).unwrap();
                        let unmoved =
                            (a.node, a.start.to_bits()) == (node, start.to_bits());
                        let killed_later = kills
                            .iter()
                            .any(|&(t, g)| g == gid && t >= rec.time);
                        assert!(
                            unmoved || killed_later,
                            "{ctx}: replan at {} moved started task {gid:?} \
                             without a kill",
                            rec.time
                        );
                    }
                }
            }
        }
    }
}

/// Straggler reverts never touch a dispatched task: the number of
/// realized (started) placements is monotone over the event log, and
/// reverted counts in replan records are consistent with the composite
/// sizes handed to the heuristic.
#[test]
fn prop_replan_accounting_is_consistent() {
    let prob = Dataset::Synthetic.instance(10, 77);
    let cfg = SimConfig {
        noise_std: 0.5,
        noise_seed: 4,
        reaction: Reaction::LastK {
            k: 3,
            threshold: 0.1,
        },
        record_frozen: true,
        full_refresh: false,
        faults: dts::sim::FaultConfig::NONE,
    };
    let mut rc = ReactiveCoordinator::new(Policy::LastK(5), SchedulerKind::Heft.make(1), cfg);
    let res = rc.run(&prob);
    assert!(res.n_straggler_replans() > 0, "config chosen to trigger stragglers");
    for rec in &res.replans {
        if rec.straggler {
            // straggler replans only ever re-place reverted tasks
            assert_eq!(rec.n_pending, rec.n_reverted, "at {}", rec.time);
            assert!(rec.n_reverted > 0, "empty straggler replans are skipped");
        } else {
            // arrival replans add the new graph's tasks on top
            assert!(rec.n_pending >= rec.n_reverted);
        }
        // nothing frozen is ever pending again
        for &(gid, _, _) in &rec.frozen {
            assert!(res.schedule.get(gid).is_some());
        }
    }
}
