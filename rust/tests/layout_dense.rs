//! §Layout integration pins (PR 6): the dense-id universe is a true
//! `Gid ↔ DenseId` bijection on every dataset, and the CSR/SoA/dense-id
//! production coordinator is bit-exact against the retained AoS/map
//! reference implementation on every heuristic — the memory-layout
//! overhaul may change *how* the hot path computes, never *what*.

use dts::coordinator::{run_reference, Coordinator, Policy};
use dts::graph::Gid;
use dts::schedule::Schedule;
use dts::schedulers::SchedulerKind;
use dts::workloads::Dataset;

const DATASETS: [Dataset; 4] = [
    Dataset::Synthetic,
    Dataset::RiotBench,
    Dataset::WfCommons,
    Dataset::Adversarial,
];

fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

/// Property: `DenseIds` round-trips every task of the instance exactly
/// once — `gid → id → gid` is the identity, the dense indices cover
/// `0..len` without collision, and the flat `gids()` column agrees with
/// the per-index accessor.
#[test]
fn dense_id_bijection_roundtrips_on_all_datasets() {
    for dataset in DATASETS {
        for scale in [1usize, 8] {
            let prob = dataset.instance(scale, 42);
            let ids = prob.dense_ids();
            assert_eq!(ids.len(), prob.total_tasks(), "{dataset:?}×{scale}");
            assert_eq!(ids.n_graphs(), prob.graphs.len());
            assert!(ids.matches(prob.graphs.iter().map(|(_, g)| g.n_tasks())));
            let mut seen = vec![false; ids.len()];
            for (j, (_, g)) in prob.graphs.iter().enumerate() {
                for t in 0..g.n_tasks() {
                    let gid = Gid::new(j, t);
                    let d = ids.id(gid);
                    assert_eq!(ids.gid(d), gid, "{dataset:?}×{scale} {gid}");
                    let ix = ids.ix(gid);
                    assert_eq!(ix, d.0 as usize);
                    assert!(!seen[ix], "{dataset:?}×{scale}: dense index {ix} collides");
                    seen[ix] = true;
                    assert_eq!(*ids.gid_ref(ix), gid);
                    assert_eq!(ids.gids()[ix], gid);
                }
            }
            assert!(seen.iter().all(|&s| s), "dense indices must cover 0..len");
        }
    }
}

/// Differential: the production coordinator (arena-built CSR composite,
/// SoA columns, dense-id schedule store, SoA timelines) is bit-exact
/// against the retained allocating reference coordinator for every
/// heuristic in the extended grid, on every dataset, for both a
/// windowed and a fully preemptive policy.
#[test]
fn dense_layout_matches_map_reference_on_every_heuristic() {
    for dataset in DATASETS {
        let prob = dataset.instance(6, 11);
        for kind in SchedulerKind::EXTENDED {
            for policy in [Policy::LastK(3), Policy::Preemptive] {
                let (want, _) = run_reference(policy, kind.make(0), &prob);
                let mut c = Coordinator::new(policy, kind.make(0));
                let got = c.run(&prob);
                assert_eq!(
                    sig(&got.schedule),
                    sig(&want),
                    "{dataset:?} {policy:?} {}",
                    kind.name()
                );
            }
        }
    }
}
