//! Differential harness for the **incremental dirty-cone belief
//! refresh** (`Sim::refresh_belief_incremental`) against the retained
//! full-plan oracle (`Sim::refresh_belief_full`, selected through
//! [`SimConfig::full_refresh`]; these tests use the config switch — an
//! env toggle would race across the parallel test harness).  Under a
//! forced `DTS_FULL_REFRESH=1` run both sides resolve to the oracle:
//! every equivalence test then trivially holds (full ≡ full), and the
//! one test whose assertions *require* the incremental mode
//! ([`sublinear_refresh_on_bursty_50_graph_composite`]) skips itself,
//! so the whole-process A/B run in `.claude/skills/verify/SKILL.md`
//! stays green.
//!
//! Every downstream metric of the reproduction (stretch, tardiness,
//! Jain, deadline misses) reads the belief schedule, so the refresh
//! rewrite must be **bit-exact**, not approximately right:
//!
//! * the full controller matrix — all four datasets × {σ 0, 0.3} ×
//!   {`L3@0.25`, `A3-20`, `B3`, `D3`} — pins realized schedules, event
//!   logs, replan records and every schedule-derived metric;
//! * refresh edge cases: replans with zero pending tasks,
//!   revert-of-everything, a straggler firing after sibling graphs
//!   already completed, and the deadline/bursty scenario axis;
//! * the **sublinearity pin** ([`ReplanRecord::n_refreshed`]): the
//!   dirty cone never exceeds the oracle's full re-derivation, and on a
//!   50-graph bursty composite the same-instant batch arrivals must
//!   re-derive *nothing* while the oracle re-walks the whole backlog —
//!   the operation-count regression the §V.E scaling argument rests on.
//!
//! [`SimConfig::full_refresh`]: dts::sim::SimConfig::full_refresh
//! [`ReplanRecord::n_refreshed`]: dts::sim::ReplanRecord::n_refreshed

use dts::coordinator::{DynamicProblem, Policy};
use dts::graph::Gid;
use dts::metrics::Metric;
use dts::policy::PolicySpec;
use dts::schedulers::SchedulerKind;
use dts::sim::{replay, Reaction, ReactiveCoordinator, SimConfig, SimResult};
use dts::workloads::{
    ArrivalModel, Dataset, DeadlineModel, Scenario, WeightModel, DEFAULT_LOAD,
};

/// Straggler driver of one differential run: the built-in PR-2 reaction
/// or a policy-engine controller spec.
#[derive(Clone, Debug)]
enum Ctl {
    Reaction(Reaction),
    Spec(PolicySpec),
}

fn run_mode(
    prob: &DynamicProblem,
    policy: Policy,
    seed: u64,
    noise_std: f64,
    ctl: &Ctl,
    full_refresh: bool,
) -> SimResult {
    let mut cfg = SimConfig {
        noise_std,
        noise_seed: seed ^ 0xA11CE,
        reaction: Reaction::None,
        record_frozen: true,
        full_refresh,
        faults: dts::sim::FaultConfig::NONE,
    };
    let mut rc = match ctl {
        Ctl::Reaction(r) => {
            cfg.reaction = *r;
            ReactiveCoordinator::new(policy, SchedulerKind::Heft.make(seed), cfg)
        }
        Ctl::Spec(spec) => ReactiveCoordinator::with_policy(
            policy,
            SchedulerKind::Heft.make(seed),
            cfg,
            spec.make(),
        ),
    };
    rc.run(prob)
}

fn sig(s: &dts::schedule::Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

/// Bit-exact equivalence of an incremental run against its full-refresh
/// oracle twin: realized schedule, event log, replan records (times,
/// kinds, revert/pending counts, frozen snapshots) and every
/// schedule-derived metric.  Wall-clock fields and `n_refreshed` are
/// intentionally exempt — the work *counts* are the optimization, the
/// cone may only ever be smaller.
fn assert_equiv(prob: &DynamicProblem, fast: &SimResult, oracle: &SimResult, ctx: &str) {
    assert_eq!(sig(&fast.schedule), sig(&oracle.schedule), "{ctx}: schedule");
    assert_eq!(fast.log, oracle.log, "{ctx}: event log");
    assert_eq!(fast.replans.len(), oracle.replans.len(), "{ctx}: replans");
    for (i, (a, b)) in fast.replans.iter().zip(oracle.replans.iter()).enumerate() {
        assert_eq!(a.time.to_bits(), b.time.to_bits(), "{ctx}: replan {i} time");
        assert_eq!(
            (a.straggler, a.n_reverted, a.n_pending),
            (b.straggler, b.n_reverted, b.n_pending),
            "{ctx}: replan {i} shape"
        );
        assert_eq!(a.frozen, b.frozen, "{ctx}: replan {i} frozen prefix");
        assert!(
            a.n_refreshed <= b.n_refreshed,
            "{ctx}: replan {i} cone {} exceeds the full oracle's {}",
            a.n_refreshed,
            b.n_refreshed
        );
    }
    // every schedule-derived metric axis, bitwise (runtime_s is wall
    // clock and naturally varies)
    let fm = fast.metrics(prob);
    let om = oracle.metrics(prob);
    for m in Metric::ALL {
        if m == Metric::Runtime {
            continue;
        }
        assert_eq!(
            fm.get(m).to_bits(),
            om.get(m).to_bits(),
            "{ctx}: metric {}",
            m.name()
        );
    }
    // both executions replay §II-valid
    let rep = replay(&fast.schedule, &prob.graphs, &prob.network);
    assert!(rep.errors.is_empty(), "{ctx}: {:?}", &rep.errors[..rep.errors.len().min(3)]);
}

/// THE MATRIX: all four datasets × {σ 0, 0.3} × the four controller
/// families of the acceptance grid — Last-K `L3@0.25` through the
/// built-in reaction, AIMD `A3-20@0.25τ2`, token-bucket
/// `B3@0.25r1b4`, and deadline-urgency `D3@0.25` (recency-degenerate on
/// the deadline-free instances, urgency-ranked in the scenario test
/// below) — each incremental run bit-identical to its oracle twin.
#[test]
fn incremental_equals_full_across_datasets_noise_controllers() {
    let controllers: [(&str, Ctl); 4] = [
        (
            "L3@0.25",
            Ctl::Reaction(Reaction::LastK {
                k: 3,
                threshold: 0.25,
            }),
        ),
        (
            "A3-20",
            Ctl::Spec(PolicySpec::AdaptiveK {
                k0: 3,
                k_max: 20,
                threshold: 0.25,
                target_stretch: 2.0,
            }),
        ),
        (
            "B3",
            Ctl::Spec(PolicySpec::Budgeted {
                k: 3,
                threshold: 0.25,
                rate: 1.0,
                burst: 4.0,
            }),
        ),
        (
            "D3",
            Ctl::Spec(PolicySpec::DeadlineAware {
                k: 3,
                threshold: 0.25,
            }),
        ),
    ];
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        for &noise in &[0.0, 0.3] {
            for (ci, (name, ctl)) in controllers.iter().enumerate() {
                let seed = 9000 + 101 * di as u64 + 11 * ci as u64;
                let prob = dataset.instance(9, seed);
                let fast = run_mode(&prob, Policy::LastK(5), seed, noise, ctl, false);
                let oracle = run_mode(&prob, Policy::LastK(5), seed, noise, ctl, true);
                let ctx = format!("{} σ{noise} {name}", dataset.name());
                assert_equiv(&prob, &fast, &oracle, &ctx);
            }
        }
    }
}

/// Edge: replans whose belief refresh has **zero pending tasks** to
/// re-derive — the first arrival of a run (empty belief) and, under a
/// non-preemptive arrival policy on a single-graph instance, every
/// refresh of the run.
#[test]
fn zero_pending_refresh_matches_oracle() {
    let full = Dataset::WfCommons.instance(4, 3);
    let prob = DynamicProblem::new(full.network.clone(), full.graphs[..1].to_vec());
    let ctl = Ctl::Reaction(Reaction::LastK {
        k: 3,
        threshold: 0.1,
    });
    let fast = run_mode(&prob, Policy::NonPreemptive, 3, 0.5, &ctl, false);
    let oracle = run_mode(&prob, Policy::NonPreemptive, 3, 0.5, &ctl, true);
    assert_equiv(&prob, &fast, &oracle, "single-graph NP");
    // the first arrival refreshes an empty belief
    assert_eq!(fast.replans[0].n_refreshed, 0);
    assert_eq!(oracle.replans[0].n_refreshed, 0);
}

/// Edge: **revert-of-everything** — a fully preemptive arrival policy
/// plus an unbounded straggler window reverts every pending task at
/// every replan, leaving the refresh nothing to re-derive (the whole
/// backlog goes back to the heuristic instead).
#[test]
fn revert_everything_matches_oracle() {
    let prob = Dataset::Synthetic.instance(10, 21);
    let ctl = Ctl::Reaction(Reaction::LastK {
        k: usize::MAX,
        threshold: 0.05,
    });
    let fast = run_mode(&prob, Policy::Preemptive, 21, 0.5, &ctl, false);
    let oracle = run_mode(&prob, Policy::Preemptive, 21, 0.5, &ctl, true);
    assert_equiv(&prob, &fast, &oracle, "P + unbounded straggler window");
    assert!(fast.n_straggler_replans() > 0, "stragglers must fire");
    for rec in &fast.replans {
        if rec.straggler {
            // everything pending was reverted, so nothing was re-derived
            assert_eq!(rec.n_refreshed, 0, "at {}", rec.time);
        }
    }
}

/// Edge: a straggler firing **after sibling graphs already completed**
/// — the completed graphs' snapped truths must stay inert in the belief
/// while the replan reshapes the survivors.  Seeds are scanned until
/// the scenario actually occurs (a straggler replan strictly after the
/// first graph completion), and every scanned run must be bit-exact.
#[test]
fn straggler_after_completed_sibling_matches_oracle() {
    let ctl = Ctl::Reaction(Reaction::LastK {
        k: 4,
        threshold: 0.05,
    });
    let mut scenario_seen = false;
    for seed in 0..5u64 {
        let prob = Dataset::Synthetic.instance(12, 300 + seed);
        let fast = run_mode(&prob, Policy::LastK(5), seed, 0.6, &ctl, false);
        let oracle = run_mode(&prob, Policy::LastK(5), seed, 0.6, &ctl, true);
        assert_equiv(&prob, &fast, &oracle, &format!("sibling seed {seed}"));
        // earliest graph completion (max realized finish per graph)
        let first_done = (0..prob.graphs.len())
            .map(|gi| {
                (0..prob.graphs[gi].1.n_tasks())
                    .map(|t| fast.schedule.get(Gid::new(gi, t)).unwrap().finish)
                    .fold(f64::NEG_INFINITY, f64::max)
            })
            .fold(f64::INFINITY, f64::min);
        scenario_seen |= fast
            .replans
            .iter()
            .any(|r| r.straggler && r.time > first_done);
    }
    assert!(
        scenario_seen,
        "no seed produced a straggler replan after a completed graph"
    );
}

/// Edge: the deadline/bursty scenario axis — heavy-tail weights,
/// critical-path×slack deadlines, same-instant burst arrivals, driven
/// by the urgency-scoped `D{k}@{θ}` controller on every dataset.
#[test]
fn deadline_bursty_scenario_matches_oracle() {
    let scen = Scenario {
        weights: WeightModel::HeavyTail { alpha: 1.5 },
        deadlines: DeadlineModel::CritPathSlack { slack: 1.5 },
        arrivals: ArrivalModel::Bursty { burst: 3 },
    };
    let ctl = Ctl::Spec(PolicySpec::DeadlineAware {
        k: 3,
        threshold: 0.1,
    });
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 500 + 13 * di as u64;
        let prob = dataset.instance_scenario(9, seed, DEFAULT_LOAD, None, &scen);
        assert!(prob.graphs.iter().all(|(_, g)| g.deadline().is_some()));
        let fast = run_mode(&prob, Policy::LastK(3), seed, 0.45, &ctl, false);
        let oracle = run_mode(&prob, Policy::LastK(3), seed, 0.45, &ctl, true);
        assert_equiv(
            &prob,
            &fast,
            &oracle,
            &format!("{} deadline/bursty", dataset.name()),
        );
    }
}

/// THE SUBLINEARITY PIN ([`dts::sim::ReplanRecord::n_refreshed`]): on a
/// 50-graph bursty composite the dirty cone must be *output-sensitive*,
/// not merely correct.
///
/// Two guarantees are asserted:
/// * per replan, the cone never exceeds the oracle's full
///   re-derivation (also enforced inside `assert_equiv`);
/// * a same-instant batch arrival after the first in its batch changes
///   **nothing** the belief depends on — no reverts under NP, no
///   observations between same-time arrivals, floors already at `now` —
///   so the incremental refresh must re-derive **zero** tasks where the
///   full oracle re-walks the entire backlog.  That is the
///   O(pending) → O(dirty cone) separation, pinned without wall clocks.
///
/// A straggler-replan witness (strictly smaller cone than the oracle on
/// a busy backlog) is asserted when such replans occur.
#[test]
fn sublinear_refresh_on_bursty_50_graph_composite() {
    if std::env::var_os("DTS_FULL_REFRESH").is_some_and(|v| v != "0") {
        // the env override forces the oracle on BOTH runs (the escape
        // hatch outranks the config switch), which makes the strict
        // cone-smaller-than-backlog assertions below vacuously false —
        // there is no incremental side to measure
        eprintln!("skipping sublinearity pin: DTS_FULL_REFRESH forces the full oracle");
        return;
    }
    let scen = Scenario {
        weights: WeightModel::Unit,
        deadlines: DeadlineModel::None,
        arrivals: ArrivalModel::Bursty { burst: 5 },
    };
    let prob = Dataset::Synthetic.instance_scenario(50, 7, DEFAULT_LOAD, None, &scen);
    let ctl = Ctl::Reaction(Reaction::LastK {
        k: 2,
        threshold: 0.1,
    });
    let fast = run_mode(&prob, Policy::NonPreemptive, 7, 0.3, &ctl, false);
    let oracle = run_mode(&prob, Policy::NonPreemptive, 7, 0.3, &ctl, true);
    assert_equiv(&prob, &fast, &oracle, "bursty 50-graph composite");
    assert!(
        fast.n_straggler_replans() > 0,
        "scenario must exercise straggler replans"
    );

    // batch arrivals: an untouched belief re-derives nothing, while the
    // oracle re-walks the whole backlog
    let zero_cone_on_busy_backlog = fast
        .replans
        .iter()
        .zip(oracle.replans.iter())
        .any(|(a, b)| !a.straggler && b.n_refreshed >= 10 && a.n_refreshed == 0);
    assert!(
        zero_cone_on_busy_backlog,
        "no batch arrival hit the zero-cone fast path (oracle totals: {:?})",
        oracle
            .replans
            .iter()
            .map(|r| r.n_refreshed)
            .collect::<Vec<_>>()
    );

    // run-level: the cone total is strictly below the oracle's
    assert!(
        fast.n_refreshed_total() < oracle.n_refreshed_total(),
        "incremental total {} not below oracle total {}",
        fast.n_refreshed_total(),
        oracle.n_refreshed_total()
    );

    // straggler witness: on a busy backlog, some straggler replan's cone
    // is strictly smaller than the oracle's full re-derivation
    let busy_stragglers: Vec<(usize, usize)> = fast
        .replans
        .iter()
        .zip(oracle.replans.iter())
        .filter(|(a, b)| a.straggler && b.n_refreshed >= 20)
        .map(|(a, b)| (a.n_refreshed, b.n_refreshed))
        .collect();
    if !busy_stragglers.is_empty() {
        assert!(
            busy_stragglers.iter().any(|&(f, o)| f < o),
            "every busy straggler replan re-derived the full backlog: {busy_stragglers:?}"
        );
    }
}
