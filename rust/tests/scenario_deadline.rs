//! Differential + behavioral harness for the **scenario axis**
//! (weights / deadlines / arrival process):
//!
//! * **Bit-identity at default knobs** — the acceptance pin: a
//!   [`Scenario::default`] instance, schedule, and full metric row are
//!   bit-identical to the pre-scenario path (`Dataset::instance`), for
//!   every dataset, through both the static coordinator and the
//!   reactive sim sweep.
//! * **Deadline axes end-to-end** — zero-slack deadlines are all
//!   missed, generous ones all met, and `weighted_tardiness ≡
//!   mean_tardiness` bit-exactly at unit weights.
//! * **DeadlineAware vs FixedLastK** — both run the same realized
//!   world; the urgency-scoped controller is §II-valid, deterministic
//!   across `--jobs`, and spends its reverts on deadline-bearing work.

use dts::coordinator::{Coordinator, DynamicProblem, Policy, Variant};
use dts::experiments::{
    run_policy_sweep_parallel, run_sim_sweep_parallel, PolicyScenario, PolicySweepConfig,
    SimScenario, SimSweepConfig,
};
use dts::graph::Gid;
use dts::metrics::{Metric, MetricRow};
use dts::policy::PolicySpec;
use dts::schedule::Schedule;
use dts::schedulers::SchedulerKind;
use dts::sim::Reaction;
use dts::workloads::{ArrivalModel, Dataset, DeadlineModel, Scenario, WeightModel, DEFAULT_LOAD};

fn sig(s: &Schedule) -> Vec<(Gid, usize, u64, u64)> {
    let mut v: Vec<(Gid, usize, u64, u64)> = s
        .iter()
        .map(|(g, a)| (*g, a.node, a.start.to_bits(), a.finish.to_bits()))
        .collect();
    v.sort();
    v
}

/// Bitwise signature of every **pre-scenario** metric axis (the new
/// deadline axes are excluded on purpose: they are new columns, and at
/// default knobs they read exactly 0.0, which the test pins separately).
fn metric_sig(m: &MetricRow) -> Vec<u64> {
    vec![
        m.total_makespan.to_bits(),
        m.mean_makespan.to_bits(),
        m.mean_flowtime.to_bits(),
        m.mean_utilization.to_bits(),
        m.mean_stretch.to_bits(),
        m.max_stretch.to_bits(),
        m.jain_fairness.to_bits(),
        m.weighted_mean_stretch.to_bits(),
        m.weighted_max_stretch.to_bits(),
        m.weighted_jain.to_bits(),
    ]
}

/// ACCEPTANCE PIN: at default scenario knobs every instance, schedule
/// and pre-existing metric is bit-identical to the pre-scenario path,
/// on all four datasets, and the new deadline columns read exactly 0.
#[test]
fn default_knobs_are_bit_identical_everywhere() {
    for (di, dataset) in Dataset::ALL.iter().enumerate() {
        let seed = 100 + di as u64;
        let a = dataset.instance(10, seed);
        let b = dataset.instance_scenario(10, seed, DEFAULT_LOAD, None, &Scenario::default());
        // instance level: arrivals, structure, weights, deadlines
        assert_eq!(a.graphs.len(), b.graphs.len());
        for ((aa, ga), (ab, gb)) in a.graphs.iter().zip(b.graphs.iter()) {
            assert_eq!(aa.to_bits(), ab.to_bits(), "{}", dataset.name());
            assert_eq!(ga.n_tasks(), gb.n_tasks());
            assert_eq!(ga.n_edges(), gb.n_edges());
            assert_eq!(ga.weight().to_bits(), gb.weight().to_bits());
            assert_eq!(ga.deadline(), None);
            assert_eq!(gb.deadline(), None);
            for t in 0..ga.n_tasks() {
                assert_eq!(ga.cost(t).to_bits(), gb.cost(t).to_bits());
            }
        }
        // schedule + metric level, through the static coordinator
        let run = |prob: &DynamicProblem| {
            let mut c = Coordinator::new(Policy::LastK(3), SchedulerKind::Heft.make(seed));
            let res = c.run(prob);
            let m = res.metrics(prob);
            (sig(&res.schedule), m)
        };
        let (sa, ma) = run(&a);
        let (sb, mb) = run(&b);
        assert_eq!(sa, sb, "{} schedules diverge at default knobs", dataset.name());
        assert_eq!(metric_sig(&ma), metric_sig(&mb), "{}", dataset.name());
        // the new columns are exactly zero on deadline-free workloads
        for m in [&ma, &mb] {
            assert_eq!(m.deadline_miss_rate, 0.0);
            assert_eq!(m.mean_tardiness, 0.0);
            assert_eq!(m.max_tardiness, 0.0);
            assert_eq!(m.weighted_tardiness, 0.0);
        }
    }
}

/// The same pin at the sweep level: a default-scenario reactive sweep
/// produces bit-identical realized cells to one whose config predates
/// the scenario field (constructed via `Scenario::default()`), and the
/// deadline columns stay zero through the whole pipeline.
#[test]
fn default_knobs_sim_sweep_is_bit_stable() {
    let variant = Variant::parse("5P-HEFT").unwrap();
    let scenarios = vec![
        SimScenario {
            noise_std: 0.35,
            reaction: Reaction::None,
        },
        SimScenario {
            noise_std: 0.35,
            reaction: Reaction::LastK {
                k: 3,
                threshold: 0.2,
            },
        },
    ];
    let cfg = SimSweepConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 8,
        trials: 2,
        seed: 11,
        load: DEFAULT_LOAD,
        variant,
        scenario: Scenario::default(),
        scenarios,
        shards: 1,
        faults: dts::sim::FaultConfig::NONE,
    };
    let serial = run_sim_sweep_parallel(&cfg, 1);
    let par = run_sim_sweep_parallel(&cfg, 4);
    for (rs, rp) in serial.rows.iter().zip(par.rows.iter()) {
        for (a, b) in rs.iter().zip(rp.iter()) {
            assert_eq!(metric_sig(&a.realized), metric_sig(&b.realized));
            assert_eq!(a.realized.deadline_miss_rate, 0.0);
            assert_eq!(a.realized.mean_tardiness, 0.0);
            assert_eq!(b.realized.weighted_tardiness, 0.0);
        }
    }
}

/// Zero-slack deadlines (deadline = arrival): every graph with work is
/// tardy by exactly its response time, so the miss rate is 1 and the
/// weighted axis equals the unweighted one bit-exactly at unit weights.
#[test]
fn zero_slack_deadlines_all_miss() {
    let scen = Scenario {
        weights: WeightModel::Unit,
        deadlines: DeadlineModel::CritPathSlack { slack: 0.0 },
        arrivals: ArrivalModel::Poisson,
    };
    let prob = Dataset::Synthetic.instance_scenario(10, 5, DEFAULT_LOAD, None, &scen);
    for (arrival, g) in &prob.graphs {
        assert_eq!(g.deadline(), Some(*arrival), "slack 0 → deadline = arrival");
    }
    let mut c = Coordinator::new(Policy::NonPreemptive, SchedulerKind::Heft.make(0));
    let res = c.run(&prob);
    let m = res.metrics(&prob);
    assert_eq!(m.deadline_miss_rate, 1.0);
    assert!(m.mean_tardiness > 0.0);
    assert!(m.max_tardiness >= m.mean_tardiness);
    // tardiness = finish − arrival = the per-graph response time; its
    // mean is exactly the §V.B mean makespan here
    assert_eq!(m.mean_tardiness.to_bits(), m.mean_makespan.to_bits());
    // unit weights: weighted ≡ unweighted, bit for bit
    assert_eq!(m.weighted_tardiness.to_bits(), m.mean_tardiness.to_bits());
}

/// Generous deadlines are all met: miss rate 0, zero tardiness on every
/// axis — the degenerate "all-graphs-met" convention.
#[test]
fn generous_deadlines_all_met() {
    let scen = Scenario {
        weights: WeightModel::Unit,
        deadlines: DeadlineModel::CritPathSlack { slack: 1e6 },
        arrivals: ArrivalModel::Poisson,
    };
    let prob = Dataset::RiotBench.instance_scenario(8, 5, DEFAULT_LOAD, None, &scen);
    let mut c = Coordinator::new(Policy::Preemptive, SchedulerKind::Cpop.make(0));
    let res = c.run(&prob);
    let m = res.metrics(&prob);
    assert_eq!(m.deadline_miss_rate, 0.0);
    assert_eq!(m.mean_tardiness, 0.0);
    assert_eq!(m.max_tardiness, 0.0);
    assert_eq!(m.weighted_tardiness, 0.0);
}

/// Non-unit weights actually reach the weighted axes through a full
/// scenario instance (the PR-3 machinery ran on degenerate input until
/// now): with heavy-tail weights the weighted mean stretch must differ
/// from the unweighted one.
#[test]
fn heavy_tail_weights_reach_the_weighted_axes() {
    let scen = Scenario {
        weights: WeightModel::HeavyTail { alpha: 1.5 },
        deadlines: DeadlineModel::None,
        arrivals: ArrivalModel::Poisson,
    };
    let prob = Dataset::Synthetic.instance_scenario(12, 9, DEFAULT_LOAD, None, &scen);
    let distinct: std::collections::HashSet<u64> =
        prob.graphs.iter().map(|(_, g)| g.weight().to_bits()).collect();
    assert!(distinct.len() > 1, "heavy tail must spread the weights");
    let mut c = Coordinator::new(Policy::LastK(3), SchedulerKind::Heft.make(0));
    let res = c.run(&prob);
    let m = res.metrics(&prob);
    assert_ne!(
        m.weighted_mean_stretch.to_bits(),
        m.mean_stretch.to_bits(),
        "non-unit weights must move the weighted mean"
    );
    assert!(m.weighted_max_stretch >= m.max_stretch);
}

/// DeadlineAware is deterministic across thread counts in the policy
/// sweep, on a full deadline/weight/bursty scenario, alongside the
/// fixed and budgeted controllers it competes with.
#[test]
fn deadline_aware_policy_sweep_is_deterministic() {
    let scen = Scenario {
        weights: WeightModel::Classes {
            weights: vec![1.0, 4.0, 16.0],
        },
        deadlines: DeadlineModel::CritPathSlack { slack: 1.2 },
        arrivals: ArrivalModel::Bursty { burst: 2 },
    };
    let cfg = PolicySweepConfig {
        dataset: Dataset::Synthetic,
        n_graphs: 8,
        trials: 2,
        seed: 23,
        load: DEFAULT_LOAD,
        variant: Variant::parse("5P-HEFT").unwrap(),
        scenario: scen,
        scenarios: vec![
            PolicyScenario {
                noise_std: 0.4,
                spec: PolicySpec::None,
            },
            PolicyScenario {
                noise_std: 0.4,
                spec: PolicySpec::FixedLastK {
                    k: 3,
                    threshold: 0.15,
                },
            },
            PolicyScenario {
                noise_std: 0.4,
                spec: PolicySpec::DeadlineAware {
                    k: 3,
                    threshold: 0.15,
                },
            },
        ],
        faults: dts::sim::FaultConfig::NONE,
    };
    let serial = run_policy_sweep_parallel(&cfg, 1);
    assert_eq!(serial.labels[2], "σ0.40/D3@0.15");
    for jobs in [2, 5] {
        let par = run_policy_sweep_parallel(&cfg, jobs);
        for (rs, rp) in serial.rows.iter().zip(par.rows.iter()) {
            for (a, b) in rs.iter().zip(rp.iter()) {
                assert_eq!(
                    a.realized.total_makespan.to_bits(),
                    b.realized.total_makespan.to_bits()
                );
                assert_eq!(
                    a.realized.weighted_tardiness.to_bits(),
                    b.realized.weighted_tardiness.to_bits()
                );
                assert_eq!(a.cost.reverted_tasks, b.cost.reverted_tasks);
                assert_eq!(a.cost.straggler_replans, b.cost.straggler_replans);
            }
        }
    }
    // the deadline axes are populated in the sweep outputs
    let csv = serial.to_csv();
    assert!(csv.contains("deadline_miss_rate"));
    assert!(csv.contains("w:classes3+d:s1.2+a:burst2"));
    let any_miss = (0..serial.labels.len())
        .any(|si| serial.realized_mean(si, Metric::DeadlineMissRate) > 0.0);
    assert!(any_miss, "slack-1.2 deadlines under bursty load should miss");
}
