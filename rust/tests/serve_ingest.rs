//! Ingest hardening: the `dts serve` request loop never panics and
//! never corrupts coordinator state, no matter what bytes arrive.
//!
//! A deterministic [`Xoshiro256pp`]-driven generator produces thousands
//! of malformed lines — truncated JSON, printable byte soup, wrong
//! shapes, unknown ops, bad graph ids, out-of-range and duplicate
//! arrivals, foreign trace documents — and the suite pins the error
//! schema documented in `docs/SERVE.md`:
//!
//! * every bad line yields **exactly one** `{"kind":"error",…}` record,
//!   itself a single-line JSON object with a stable `code` from the
//!   documented set and the 1-based request-line number;
//! * server state (journal, pending set, arrival count) is untouched —
//!   [`ServeServer::state_fingerprint`] is the oracle;
//! * a valid request stream interleaved with malformed lines produces
//!   the **identical** epoch output as the clean stream.
//!
//! The same parser is exposed as a fuzz entry point behind the `fuzz`
//! feature (`dts::serve::protocol::fuzz_ingest_line`); this suite is the
//! fuzzer-free CI stand-in driving the identical code path.

use dts::coordinator::Variant;
use dts::json::Value;
use dts::prng::Xoshiro256pp;
use dts::serve::{parse_request, Controller, ServeConfig, ServeServer};
use dts::sim::Reaction;
use dts::workloads::{Dataset, Scenario, DEFAULT_LOAD};

const GRAPHS: usize = 5;

/// Documented error codes (docs/SERVE.md) — the closed set every
/// rejection must map into.
const CODES: [&str; 8] = [
    "parse",
    "shape",
    "op",
    "field",
    "range",
    "duplicate",
    "trace",
    "snapshot",
];

fn cfg() -> ServeConfig {
    ServeConfig {
        dataset: Dataset::Synthetic,
        n_graphs: GRAPHS,
        seed: 3,
        variant: Variant::parse("5P-HEFT").unwrap(),
        noise_std: 0.3,
        controller: Controller::Reaction(Reaction::LastK {
            k: 3,
            threshold: 0.25,
        }),
        shards: 1,
        jobs: 1,
        load: DEFAULT_LOAD,
        scenario: Scenario::default(),
        faults: dts::sim::FaultConfig::NONE,
    }
}

/// One malformed request line.  `dup_graph` is a graph id the server has
/// already admitted (for the duplicate class).
fn bad_line(rng: &mut Xoshiro256pp, dup_graph: usize) -> String {
    match rng.below(9) {
        // strict prefix of a valid request: never valid JSON
        0 => {
            let full = r#"{"op":"arrive","graph":3}"#;
            let cut = 1 + rng.below(full.len() - 1);
            full[..cut].to_string()
        }
        // printable byte soup ('!'..='z': no whitespace, so never
        // skipped as blank; at best parses as a bare non-object)
        1 => {
            let len = 1 + rng.below(40);
            (0..len)
                .map(|_| (b'!' + rng.below(90) as u8) as char)
                .collect()
        }
        // valid JSON, wrong shape
        2 => match rng.below(3) {
            0 => format!("[{}]", rng.below(100)),
            1 => format!("{}", rng.below(100)),
            _ => "\"a string\"".to_string(),
        },
        // unknown op (prefixed so it can never collide with a real one)
        3 => {
            let len = 1 + rng.below(6);
            let tail: String = (0..len)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            format!("{{\"op\":\"x{tail}\"}}")
        }
        // arrive with an invalid graph value
        4 => {
            let bad = ["-1", "1.5", "\"3\"", "1e300", "null", "true"];
            format!(
                "{{\"op\":\"arrive\",\"graph\":{}}}",
                bad[rng.below(bad.len())]
            )
        }
        // missing op / non-string op
        5 => match rng.below(2) {
            0 => format!("{{\"graph\":{}}}", rng.below(10)),
            _ => format!("{{\"op\":{}}}", rng.below(10)),
        },
        // out-of-range arrival (valid request, instance rejects)
        6 => format!("{{\"op\":\"arrive\",\"graph\":{}}}", GRAPHS + rng.below(1000)),
        // duplicate arrival
        7 => format!("{{\"op\":\"arrive\",\"graph\":{dup_graph}}}"),
        // trace-routed documents: foreign formats and invalid traces
        _ => match rng.below(3) {
            0 => "{\"format\":\"dts-trace-v9\"}".to_string(),
            1 => "{\"format\":17}".to_string(),
            _ => "{\"format\":\"dts-sim-trace-v1\",\"n_nodes\":3}".to_string(),
        },
    }
}

#[test]
fn malformed_lines_yield_one_error_and_leave_state_untouched() {
    let mut server = ServeServer::new(cfg());
    let mut out = Vec::new();
    // admit one graph so the duplicate class has a target
    server.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
    let fingerprint = server.state_fingerprint();

    let mut rng = Xoshiro256pp::seed_from_u64(0xBAD_1E57);
    let mut seen_codes = std::collections::BTreeSet::new();
    for i in 0..2000 {
        let line = bad_line(&mut rng, 0);
        let before = server.lines_handled();
        let mut eout = Vec::new();
        server.handle_line(&line, &mut eout);
        assert_eq!(eout.len(), 1, "iter {i}: line {line:?} → {eout:?}");
        let v = Value::from_str(&eout[0])
            .unwrap_or_else(|e| panic!("iter {i}: error record not JSON ({e}): {}", eout[0]));
        assert_eq!(
            v.get("kind").and_then(|k| k.as_str()),
            Some("error"),
            "iter {i}: {line:?} → {}",
            eout[0]
        );
        let code = v.get("code").and_then(|c| c.as_str()).unwrap().to_string();
        assert!(CODES.contains(&code.as_str()), "iter {i}: code {code:?}");
        seen_codes.insert(code);
        assert_eq!(
            v.get("line").and_then(|l| l.as_usize()),
            Some(before as usize + 1),
            "iter {i}: error line number"
        );
        assert!(v.get("reason").and_then(|r| r.as_str()).is_some());
        assert_eq!(
            server.state_fingerprint(),
            fingerprint,
            "iter {i}: state mutated by {line:?}"
        );
    }
    // the generator must actually exercise the documented code space
    for code in ["parse", "shape", "op", "field", "range", "duplicate", "trace"] {
        assert!(seen_codes.contains(code), "generator never produced {code:?}");
    }
}

#[test]
fn snapshot_without_path_is_a_structured_error() {
    let mut server = ServeServer::new(cfg());
    let mut out = Vec::new();
    server.handle_line("{\"op\":\"snapshot\"}", &mut out);
    assert_eq!(out.len(), 1);
    assert!(out[0].contains("\"code\":\"snapshot\""), "{}", out[0]);
}

#[test]
fn parser_never_panics_on_byte_soup() {
    // the fuzz_ingest_line contract, minus the feature gate: arbitrary
    // printable strings through the request parser land in Ok or Err,
    // never a panic
    let mut rng = Xoshiro256pp::seed_from_u64(0xF0CC_F00D);
    for _ in 0..20_000 {
        let len = rng.below(60);
        let line: String = (0..len)
            .map(|_| (b' ' + rng.below(95) as u8) as char)
            .collect();
        let _ = parse_request(line.trim());
    }
}

#[test]
fn interleaved_garbage_does_not_perturb_the_epoch() {
    // clean session
    let mut clean = ServeServer::new(cfg());
    let mut clean_out = Vec::new();
    for g in 0..GRAPHS {
        clean.handle_line(&format!("{{\"op\":\"arrive\",\"graph\":{g}}}"), &mut clean_out);
    }
    clean.handle_line("{\"op\":\"run\"}", &mut clean_out);

    // same valid stream with malformed lines interspersed
    let mut dirty = ServeServer::new(cfg());
    let mut dirty_out = Vec::new();
    let mut rng = Xoshiro256pp::seed_from_u64(0xD1271);
    for g in 0..GRAPHS {
        for _ in 0..rng.below(3) {
            let mut junk = Vec::new();
            dirty.handle_line(&bad_line(&mut rng, 0), &mut junk);
        }
        dirty.handle_line(&format!("{{\"op\":\"arrive\",\"graph\":{g}}}"), &mut dirty_out);
    }
    let mut junk = Vec::new();
    dirty.handle_line(&bad_line(&mut rng, 0), &mut junk);
    dirty.handle_line("{\"op\":\"run\"}", &mut dirty_out);

    // identical acks, decision stream and summary — except the summary
    // itself which is identical too (epoch numbering is by successful
    // epochs, not by line count)
    assert_eq!(clean_out, dirty_out);
    assert_eq!(clean.epochs(), dirty.epochs());
}

#[test]
fn whitespace_lines_are_ignored_entirely() {
    let mut server = ServeServer::new(cfg());
    let mut out = Vec::new();
    for blank in ["", "   ", "\t", "  \t  "] {
        server.handle_line(blank, &mut out);
    }
    assert!(out.is_empty());
    assert_eq!(server.lines_handled(), 0);
}

/// Drive the bounded-read I/O loop ([`dts::serve::pump`]) over an
/// in-memory session with a small `--max-line-bytes`: an oversized
/// request line yields **exactly one** `{"kind":"error","code":"range"}`
/// record, the line is fully drained (the session recovers and keeps
/// parsing), and server state is untouched.
#[test]
fn oversized_lines_yield_one_range_error_and_session_recovers() {
    use dts::serve::{pump, ServeOptions, SessionEnd};
    use std::io::BufReader;

    let limit = 64usize;
    let opts = ServeOptions {
        max_line_bytes: limit,
        ..ServeOptions::default()
    };
    let big = format!(
        "{{\"op\":\"arrive\",\"graph\":1,\"pad\":\"{}\"}}",
        "x".repeat(limit * 5)
    );
    assert!(big.len() > limit);
    let input = format!(
        "{{\"op\":\"arrive\",\"graph\":0}}\n{big}\n{{\"op\":\"arrive\",\"graph\":1}}\n"
    );

    let mut server = ServeServer::new(cfg());
    let mut raw = Vec::new();
    // a tiny buffer forces the multi-chunk drain path of the reader
    let end = pump(
        &mut server,
        BufReader::with_capacity(8, input.as_bytes()),
        &mut raw,
        &opts,
    )
    .unwrap();
    assert_eq!(end, SessionEnd::Eof);

    let out: Vec<String> = String::from_utf8(raw)
        .unwrap()
        .lines()
        .map(str::to_string)
        .collect();
    // ack, one range error, ack — the oversized line never splits into
    // several errors and never swallows the next request
    assert_eq!(out.len(), 3, "{out:?}");
    let err = Value::from_str(&out[1]).unwrap();
    assert_eq!(err.get("kind").and_then(|k| k.as_str()), Some("error"));
    assert_eq!(err.get("code").and_then(|c| c.as_str()), Some("range"));
    assert_eq!(err.get("line").and_then(|l| l.as_usize()), Some(2));
    assert!(!out[0].contains("\"error\""), "{}", out[0]);
    assert!(!out[2].contains("\"error\""), "{}", out[2]);
    // both valid arrivals were admitted around the oversized line
    assert_eq!(server.lines_handled(), 3);
}

/// An oversized-only session leaves the state fingerprint untouched —
/// the drop is accounted as one request + one error, never as state.
#[test]
fn oversized_line_leaves_state_fingerprint_untouched() {
    use dts::serve::{pump, ServeOptions};
    use std::io::BufReader;

    let opts = ServeOptions {
        max_line_bytes: 16,
        ..ServeOptions::default()
    };
    let mut server = ServeServer::new(cfg());
    let mut out = Vec::new();
    server.handle_line("{\"op\":\"arrive\",\"graph\":0}", &mut out);
    let fingerprint = server.state_fingerprint();

    let input = format!("{}\n", "y".repeat(400));
    let mut raw = Vec::new();
    pump(
        &mut server,
        BufReader::with_capacity(8, input.as_bytes()),
        &mut raw,
        &opts,
    )
    .unwrap();
    assert_eq!(server.state_fingerprint(), fingerprint);
    let text = String::from_utf8(raw).unwrap();
    assert_eq!(text.lines().count(), 1, "{text:?}");
    assert!(text.contains("\"code\":\"range\""), "{text:?}");
}

/// A session with oversized lines interspersed produces the identical
/// decision stream as the clean session — the epoch output is a pure
/// function of the accepted requests.
#[test]
fn interleaved_oversized_lines_do_not_perturb_the_epoch() {
    use dts::serve::{pump, ServeOptions};
    use std::io::BufReader;

    let opts = ServeOptions {
        max_line_bytes: 48,
        ..ServeOptions::default()
    };
    let valid: Vec<String> = (0..GRAPHS)
        .map(|g| format!("{{\"op\":\"arrive\",\"graph\":{g}}}"))
        .chain(std::iter::once("{\"op\":\"run\"}".to_string()))
        .collect();

    let run_session = |input: &str| {
        let mut server = ServeServer::new(cfg());
        let mut raw = Vec::new();
        pump(
            &mut server,
            BufReader::with_capacity(8, input.as_bytes()),
            &mut raw,
            &opts,
        )
        .unwrap();
        let lines: Vec<String> = String::from_utf8(raw)
            .unwrap()
            .lines()
            .filter(|l| !l.contains("\"kind\":\"error\""))
            .map(str::to_string)
            .collect();
        (lines, server.epochs().to_vec())
    };

    let clean_input = valid.join("\n") + "\n";
    let dirty_input = valid
        .iter()
        .flat_map(|l| [format!("z{}", "z".repeat(100)), l.clone()])
        .collect::<Vec<_>>()
        .join("\n")
        + "\n";

    let (clean, clean_epochs) = run_session(&clean_input);
    let (dirty, dirty_epochs) = run_session(&dirty_input);
    assert_eq!(clean, dirty);
    assert_eq!(clean_epochs, dirty_epochs);
}
